//! Elastic-fleet scenario sweep: diurnal and burst-inversion demand ×
//! scaling policy, against a static fleet at equal peak capacity.
//!
//! The acceptance question this bench answers: with the §4.4
//! load-gradient autoscaler chasing a diurnal demand curve
//! (peak:trough ≥ 3:1), how many active-instance-seconds does the
//! fleet bill compared to a static fleet sized for the same peak — and
//! does DSLO attainment hold while it saves? Results (incl. the
//! `savings_vs_static` column) land in `results/elastic_scaling_*.csv`.

use polyserve::analysis::ServingMode;
use polyserve::config::{DiurnalSpec, Policy, ScalerKind, SimConfig};
use polyserve::figures::Experiment;
use polyserve::slo::TierDistribution;
use polyserve::util::benchkit::{f, full_scale, Bench};
use polyserve::util::rng::Rng;
use polyserve::util::threadpool::par_map;
use polyserve::workload::{TraceKind, Workload};

#[derive(Clone, Copy)]
struct Scenario {
    name: &'static str,
    diurnal: Option<DiurnalSpec>,
    /// §5.3-style tier-mix inversion halfway through the run.
    burst_inversion: bool,
}

const SCENARIOS: [Scenario; 3] = [
    Scenario {
        name: "diurnal_3to1",
        diurnal: Some(DiurnalSpec { peak_to_trough: 3.0, period_s: 600.0 }),
        burst_inversion: false,
    },
    Scenario {
        name: "diurnal_4to1_fast",
        diurnal: Some(DiurnalSpec { peak_to_trough: 4.0, period_s: 300.0 }),
        burst_inversion: false,
    },
    Scenario {
        name: "burst_inversion",
        diurnal: None,
        burst_inversion: true,
    },
];

/// Re-tag the workload's SLOs with the inverted tier mix for the second
/// half (arrivals and lengths untouched, so fleets see the same bytes).
fn invert_second_half(w: &mut Workload, seed: u64) {
    let d2 = TierDistribution::paper_inverted();
    let mut rng = Rng::new(seed ^ 0xB0057);
    let half = w.requests.len() / 2;
    for r in w.requests.iter_mut().skip(half) {
        if !r.slo.is_best_effort() {
            r.slo = d2.sample(&mut rng);
        }
    }
}

struct Cell {
    scenario: Scenario,
    mode: ServingMode,
    scaler: ScalerKind,
    /// Fixed fleet at peak capacity (the baseline bill).
    is_static: bool,
}

struct CellResult {
    attain: f64,
    active_instance_s: f64,
    cost_per_1k_goodput_tokens: f64,
    fleet_mean: f64,
    fleet_peak: usize,
    fleet_trough: usize,
    unfinished: usize,
}

fn run_cell(c: &Cell, n_peak: usize, requests: usize) -> CellResult {
    let cfg = SimConfig {
        trace: TraceKind::ShareGpt,
        mode: c.mode,
        policy: Policy::PolyServe,
        instances: n_peak,
        requests,
        rate_frac_of_optimal: 0.75,
        diurnal: c.scenario.diurnal,
        ..Default::default()
    };
    // Prepare against the peak fleet: this fixes the request rate (and
    // the PD prefill share) that every policy must face identically.
    // Elastic cells then retune the *cluster* config on the same
    // Experiment — the workload is already generated and shared.
    let mut exp = Experiment::prepare(&cfg);
    if !c.is_static {
        let cfg = &mut exp.cfg;
        cfg.elastic.scaler = c.scaler;
        cfg.elastic.provision_delay_ms = 15_000;
        cfg.elastic.scale_eval_ms = 1_000;
        match c.mode {
            ServingMode::PdDisaggregated => {
                // Equal peak capacity: the static prefill cluster keeps
                // its peak size (it does not scale); only the decode
                // fleet is elastic, bounded by the static fleet's
                // decode share.
                let n_pf = ((n_peak as f64 * cfg.prefill_frac).round() as usize)
                    .clamp(1, n_peak - 1);
                let scalable_peak = n_peak - n_pf;
                cfg.elastic.min_instances = (scalable_peak / 4).max(2);
                cfg.elastic.max_instances = scalable_peak;
                cfg.instances = n_pf + cfg.elastic.min_instances;
                cfg.prefill_frac = n_pf as f64 / cfg.instances as f64;
            }
            ServingMode::Colocated => {
                cfg.elastic.min_instances = (n_peak / 4).max(2);
                cfg.elastic.max_instances = n_peak;
                cfg.instances = cfg.elastic.min_instances;
            }
        }
    }
    if c.scenario.burst_inversion {
        invert_second_half(&mut exp.workload, cfg.seed);
    }
    let res = exp.run();
    CellResult {
        attain: res.attainment.overall(),
        active_instance_s: res.cost.active_instance_ms as f64 / 1000.0,
        cost_per_1k_goodput_tokens: res.cost.cost_per_1k_goodput_tokens_s(),
        fleet_mean: if res.fleet.is_empty() {
            n_peak as f64
        } else {
            res.fleet.mean_active()
        },
        fleet_peak: if res.fleet.is_empty() { n_peak } else { res.fleet.peak_active() },
        fleet_trough: if res.fleet.is_empty() { n_peak } else { res.fleet.trough_active() },
        unfinished: res.unfinished,
    }
}

fn main() {
    let mut bench = Bench::new("elastic_scaling");
    let full = full_scale();
    let requests = if full { 30_000 } else { 4_000 };
    let n_peak = if full { 48 } else { 24 };
    let threads = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4);

    let mut cells = Vec::new();
    for scenario in SCENARIOS {
        for mode in [ServingMode::Colocated, ServingMode::PdDisaggregated] {
            cells.push(Cell { scenario, mode, scaler: ScalerKind::Off, is_static: true });
            for scaler in [ScalerKind::Gradient, ScalerKind::Threshold] {
                cells.push(Cell { scenario, mode, scaler, is_static: false });
            }
        }
    }
    let results = par_map(cells, threads, move |_, c| {
        let r = run_cell(&c, n_peak, requests);
        (c, r)
    });

    // Index static baselines for the savings column: (bill, attain).
    let static_cell = |scenario: &str, mode: ServingMode| {
        results
            .iter()
            .find(|(c, _)| c.is_static && c.scenario.name == scenario && c.mode == mode)
            .map(|(_, r)| (r.active_instance_s, r.attain))
            .unwrap_or((f64::NAN, f64::NAN))
    };

    let mut rows = Vec::new();
    for (c, r) in &results {
        let policy = if c.is_static { "static".to_string() } else { c.scaler.name().to_string() };
        let (base_bill, base_attain) = static_cell(c.scenario.name, c.mode);
        let savings = if c.is_static { 0.0 } else { 1.0 - r.active_instance_s / base_bill };
        let d_attain = r.attain - base_attain;
        rows.push(vec![
            c.scenario.name.to_string(),
            c.mode.name().to_string(),
            policy,
            f(r.attain, 3),
            f(d_attain, 3),
            f(r.active_instance_s, 1),
            f(savings, 3),
            f(r.cost_per_1k_goodput_tokens, 3),
            f(r.fleet_mean, 1),
            r.fleet_peak.to_string(),
            r.fleet_trough.to_string(),
            r.unfinished.to_string(),
        ]);
    }
    bench.table(
        "Elastic scaling: active-instance-seconds vs static fleet at equal peak capacity",
        &[
            "scenario",
            "mode",
            "policy",
            "attain",
            "d_attain_vs_static",
            "active_inst_s",
            "savings_vs_static",
            "cost_per_1k_goodput_tok",
            "fleet_mean",
            "fleet_peak",
            "fleet_trough",
            "unfinished",
        ],
        &rows,
    );
    bench.finish();
}
