//! Elastic-fleet scenario sweep: diurnal and burst-inversion demand ×
//! scaling policy (gradient / threshold / predictive) × scale-in
//! migration × elastic-prefill, against a static fleet at equal peak
//! capacity.
//!
//! The acceptance questions this bench answers: with an autoscaler
//! chasing a diurnal demand curve (peak:trough ≥ 3:1), how many
//! active-instance-seconds does the fleet bill compared to a static
//! fleet sized for the same peak, does DSLO attainment hold while it
//! saves — and does the *predictive* scaler (provisioning before the
//! ramp crests instead of reacting to saturation) beat both reactive
//! scalers on SLO-violation rate or on bill per goodput token on the
//! ramps? The long-decode scenario additionally measures how much
//! drain latency (begin_drain→retire) and bill scale-in KV migration
//! shaves off wait-drain, and the `+pf` cells let TTFT pressure scale
//! the PD prefill tier too (prefill fleet columns `pf_mean`/`pf_peak`/
//! `pf_trough`, re-routed jobs in `migrated_pf`). Results (incl. the
//! `savings_vs_static` column) land in `results/elastic_scaling_*.csv`.
//!
//! Two multi-model cells run the built-in two-model registry
//! (LLaMA-3.1-8B + Qwen2.5-32B) through the same elastic machinery: a
//! steady 70/30 diurnal mix, and a model-1 flash crowd engineered so
//! the mix planner must hot-swap warm donors' weights.
//!
//! Three adversarial cells per scaling policy score cost × attainment
//! under the `[chaos]` stressors: an MTBF-driven instance-failure
//! process (victims lose their KV and re-prefill from scratch), a spot
//! fleet whose preemption notices race a short drain grace against
//! stretched decode tails (deadline kills), and a 4× flash-crowd
//! arrival spike with no chaos at all. Every chaos cell checks exact
//! per-request token conservation against the workload's ground-truth
//! decode lengths.
//!
//! Three recovery cells put correlated rack/zone kills (a 2 × 2
//! failure-domain stripe under a domain-kill MTBF process) against the
//! recovery layer: bare, with the periodic KV-checkpoint sweep
//! (suffix-only re-prefill), and with the chaos-adaptive predictive
//! scaler on top (churn padding + the spot/on-demand policy flip).
//! The smoke gate asserts checkpointing strictly reduces lost-KV
//! tokens and the adaptive scaler holds attainment — the `recovery
//! smoke OK` marker is grep-gated in CI.
//!
//! The overload grid sweeps arrival rate from 0.5× to 3× of the peak
//! fleet's optimal goodput for {fifo, edf, edf+reject,
//! edf+reject+retry} × all three scalers, emitting the rejection-rate ×
//! tail-attainment × goodput curves of the `[overload]` layer: FIFO
//! pending queues collapse past saturation, EDF ordering holds the
//! tail, the arrival-edge admission gate sheds provably-infeasible
//! requests with typed `Rejected` outcomes, and retry-with-backoff
//! clients distinguish shed load from merely deferred load.
//!
//! `POLYSERVE_SMOKE=1` runs a tiny workload and asserts the invariants
//! (every request finishes; migration counters move only when enabled;
//! the prefill fleet moves only in `+pf` cells; both registry models
//! serve and bill; the flash crowd forces ≥ 1 model hot-swap; the
//! chaos cells see ≥ 1 failure and ≥ 1 deadline kill with zero token
//! violations; the reject cells shed ≥ 1 request at 2× saturation with
//! zero SLO violations among accepted requests, EDF never worsens the
//! FIFO TTFT tail, and edf+reject beats FIFO on accepted-request
//! attainment) so a regression fails CI outright. The `model-mix smoke
//! OK`, `chaos smoke OK`, `recovery smoke OK` and `overload smoke OK`
//! marker lines are grep-gated in CI.

use polyserve::analysis::ServingMode;
use polyserve::config::{DiurnalSpec, Policy, ScalerKind, SimConfig};
use polyserve::coordinator::sizing::split_pd_fleet;
use polyserve::figures::{size_elastic_pd_cell, Experiment};
use polyserve::slo::TierDistribution;
use polyserve::util::benchkit::{f, full_scale, smoke_scale, Bench};
use polyserve::util::rng::Rng;
use polyserve::util::threadpool::par_map;
use polyserve::workload::{RateSchedule, TraceKind, Workload};
use std::collections::HashMap;

#[derive(Clone, Copy)]
struct Scenario {
    name: &'static str,
    diurnal: Option<DiurnalSpec>,
    /// §5.3-style tier-mix inversion halfway through the run.
    burst_inversion: bool,
    /// Stretch a deterministic subset of decode lengths so drains hold
    /// long-tailed residents — the scale-in migration stress case.
    long_decode: bool,
}

const SCENARIOS: [Scenario; 4] = [
    Scenario {
        name: "diurnal_3to1",
        diurnal: Some(DiurnalSpec { peak_to_trough: 3.0, period_s: 600.0 }),
        burst_inversion: false,
        long_decode: false,
    },
    Scenario {
        name: "diurnal_4to1_fast",
        diurnal: Some(DiurnalSpec { peak_to_trough: 4.0, period_s: 300.0 }),
        burst_inversion: false,
        long_decode: false,
    },
    Scenario {
        name: "diurnal_3to1_longdec",
        diurnal: Some(DiurnalSpec { peak_to_trough: 3.0, period_s: 600.0 }),
        burst_inversion: false,
        long_decode: true,
    },
    Scenario {
        name: "burst_inversion",
        diurnal: None,
        burst_inversion: true,
        long_decode: false,
    },
];

/// Re-tag the workload's SLOs with the inverted tier mix for the second
/// half (arrivals and lengths untouched, so fleets see the same bytes).
fn invert_second_half(w: &mut Workload, seed: u64) {
    let d2 = TierDistribution::paper_inverted();
    let mut rng = Rng::new(seed ^ 0xB0057);
    let half = w.requests.len() / 2;
    for r in w.requests.iter_mut().skip(half) {
        if !r.slo.is_best_effort() {
            r.slo = d2.sample(&mut rng);
        }
    }
}

/// Deterministically stretch every 5th request's decode to 6× — the
/// long-output stragglers that hold a wait-drain open.
fn stretch_decode_tail(w: &mut Workload) {
    for r in w.requests.iter_mut().step_by(5) {
        r.decode_len = (r.decode_len * 6).min(8192);
    }
}

/// Re-tag arrivals as a model-1 flash crowd: the first third of the
/// trace is all model 0 (matching the fleet's 0-heavy initial split),
/// then every later arrival belongs to model 1. Model 0's smoothed
/// rate collapses while model 1's surges past its two-server sub-fleet,
/// so the mix planner must hot-swap warm model-0 donors — the enforced
/// model-swap case the smoke gate asserts on.
fn model_flash_crowd(w: &mut Workload) {
    let cut = w.requests.len() / 3;
    for (i, r) in w.requests.iter_mut().enumerate() {
        r.model = usize::from(i >= cut);
    }
}

/// Per-model outcome of a two-model cell (index = registry model id).
struct ModelCellResult {
    attain: [f64; 2],
    served: [u64; 2],
    bill_s: [f64; 2],
    fleet_mean: [f64; 2],
    swaps: u64,
    unfinished: usize,
}

/// One two-model elastic cell over the built-in LLaMA-8B + Qwen-32B
/// registry pair: a steady-mix diurnal run (`flash_crowd = false`) or
/// the model-1 flash crowd that forces weight hot-swaps.
fn run_model_cell(n_peak: usize, requests: usize, flash_crowd: bool) -> ModelCellResult {
    let mut cfg = SimConfig {
        trace: TraceKind::ShareGpt,
        mode: ServingMode::Colocated,
        policy: Policy::PolyServe,
        instances: n_peak,
        requests,
        rate_frac_of_optimal: 0.4,
        diurnal: (!flash_crowd)
            .then_some(DiurnalSpec { peak_to_trough: 3.0, period_s: 600.0 }),
        ..Default::default()
    };
    // A 0-heavy split so the flash crowd finds surplus model-0 donors.
    cfg.models.mix = if flash_crowd { vec![0.8, 0.2] } else { vec![0.7, 0.3] };
    cfg.models.swap_delay_ms = 2_000;
    cfg.elastic.scaler = ScalerKind::Gradient;
    cfg.elastic.provision_delay_ms = 3_000;
    cfg.elastic.scale_eval_ms = 1_000;
    cfg.elastic.migration = true;
    cfg.elastic.min_instances = 2;
    cfg.elastic.max_instances = n_peak * 2;
    let mut exp = Experiment::prepare(&cfg);
    if flash_crowd {
        model_flash_crowd(&mut exp.workload);
    }
    let res = exp.run();
    ModelCellResult {
        attain: [0, 1].map(|m| res.attainment.model_attainment(m).unwrap_or(f64::NAN)),
        served: [0, 1]
            .map(|m| res.cost.requests_served_per_model.get(m).copied().unwrap_or(0)),
        bill_s: [0, 1].map(|m| {
            res.cost.active_instance_ms_per_model.get(m).copied().unwrap_or(0) as f64
                / 1000.0
        }),
        fleet_mean: [0, 1].map(|m| res.fleet.mean_model(m)),
        swaps: res.migration.model_swaps,
        unfinished: res.unfinished,
    }
}

#[derive(Clone, Copy)]
struct Cell {
    scenario: Scenario,
    mode: ServingMode,
    scaler: ScalerKind,
    /// Scale-in KV migration on elastic cells.
    migration: bool,
    /// Elastic PD prefill tier (TTFT-pressure scaling).
    prefill_elastic: bool,
    /// Fixed fleet at peak capacity (the baseline bill).
    is_static: bool,
}

struct CellResult {
    attain: f64,
    active_instance_s: f64,
    cost_per_1k_goodput_tokens: f64,
    fleet_mean: f64,
    fleet_peak: usize,
    fleet_trough: usize,
    pf_mean: f64,
    pf_peak: usize,
    pf_trough: usize,
    drains: usize,
    drain_mean_ms: f64,
    migrated_reqs: u64,
    migrated_prefill_jobs: u64,
    migrated_kv_tokens: u64,
    unfinished: usize,
}

fn run_cell(c: &Cell, n_peak: usize, requests: usize) -> CellResult {
    let cfg = SimConfig {
        trace: TraceKind::ShareGpt,
        mode: c.mode,
        policy: Policy::PolyServe,
        instances: n_peak,
        requests,
        rate_frac_of_optimal: 0.75,
        diurnal: c.scenario.diurnal,
        ..Default::default()
    };
    // Prepare against the peak fleet: this fixes the request rate (and
    // the PD prefill share) that every policy must face identically.
    // Elastic cells then retune the *cluster* config on the same
    // Experiment — the workload is already generated and shared.
    let mut exp = Experiment::prepare(&cfg);
    if !c.is_static {
        let cfg = &mut exp.cfg;
        cfg.elastic.scaler = c.scaler;
        cfg.elastic.provision_delay_ms = 15_000;
        cfg.elastic.scale_eval_ms = 1_000;
        cfg.elastic.migration = c.migration;
        match c.mode {
            ServingMode::PdDisaggregated => {
                // Equal peak capacity: the static prefill cluster keeps
                // its peak size (it does not scale); only the decode
                // fleet is elastic, bounded by the static fleet's
                // decode share.
                let peak_frac = cfg.prefill_frac;
                size_elastic_pd_cell(cfg, n_peak, peak_frac, |sp| (sp / 4).max(2));
                if c.prefill_elastic {
                    // `+pf`: the prefill tier scales too — start at its
                    // peak share, drain to half in the trough, grow a
                    // little past peak under TTFT pressure.
                    let (n_pf, _) = split_pd_fleet(n_peak, peak_frac);
                    cfg.elastic.prefill_elastic = true;
                    cfg.elastic.prefill_min = (n_pf / 2).max(1);
                    cfg.elastic.prefill_max = n_pf + 2;
                }
            }
            ServingMode::Colocated => {
                cfg.elastic.min_instances = (n_peak / 4).max(2);
                cfg.elastic.max_instances = n_peak;
                cfg.instances = cfg.elastic.min_instances;
            }
        }
    }
    if c.scenario.burst_inversion {
        invert_second_half(&mut exp.workload, cfg.seed);
    }
    if c.scenario.long_decode {
        stretch_decode_tail(&mut exp.workload);
    }
    // Static fleets record no samples: fill the prefill columns from
    // the (constant) built fleet split.
    let n_pf_static = match c.mode {
        ServingMode::PdDisaggregated => split_pd_fleet(exp.cfg.instances, exp.cfg.prefill_frac).0,
        ServingMode::Colocated => 0,
    };
    let res = exp.run();
    CellResult {
        attain: res.attainment.overall(),
        active_instance_s: res.cost.active_instance_ms as f64 / 1000.0,
        cost_per_1k_goodput_tokens: res.cost.cost_per_1k_goodput_tokens_s(),
        fleet_mean: if res.fleet.is_empty() {
            n_peak as f64
        } else {
            res.fleet.mean_active()
        },
        fleet_peak: if res.fleet.is_empty() { n_peak } else { res.fleet.peak_active() },
        fleet_trough: if res.fleet.is_empty() { n_peak } else { res.fleet.trough_active() },
        pf_mean: if res.fleet.is_empty() { n_pf_static as f64 } else { res.fleet.mean_prefill() },
        pf_peak: if res.fleet.is_empty() { n_pf_static } else { res.fleet.peak_prefill() },
        pf_trough: if res.fleet.is_empty() { n_pf_static } else { res.fleet.trough_prefill() },
        drains: res.migration.drains(),
        drain_mean_ms: res.migration.mean_drain_latency_ms(),
        migrated_reqs: res.migration.migrated_requests,
        migrated_prefill_jobs: res.migration.migrated_prefill_jobs,
        migrated_kv_tokens: res.migration.migrated_kv_tokens,
        unfinished: res.unfinished,
    }
}

/// The three adversarial stressors the chaos cells score each scaling
/// policy under.
#[derive(Clone, Copy, PartialEq)]
enum Stressor {
    /// MTBF-driven instance failures: residents lose their KV and
    /// re-enter placement for a full re-prefill.
    Failure,
    /// An all-spot elastic fleet under MTBF preemption notices with a
    /// short drain grace, on stretched decode tails — wait-drain can't
    /// finish in time, so the hard deadline kills the instance.
    SpotPreempt,
    /// A 4× arrival spike with no chaos: pure demand stress.
    FlashCrowd,
}

impl Stressor {
    fn name(self) -> &'static str {
        match self {
            Stressor::Failure => "instance_failure",
            Stressor::SpotPreempt => "spot_preempt",
            Stressor::FlashCrowd => "flash_crowd",
        }
    }
}

struct ChaosCellResult {
    attain: f64,
    /// Spot-discounted bill (== the plain bill when nothing is spot).
    bill_s: f64,
    cost_per_1k_goodput_tokens: f64,
    failures: u64,
    preempt_notices: u64,
    preempt_drained: u64,
    deadline_kills: u64,
    replaced_requests: u64,
    lost_kv_tokens: u64,
    spot_s: f64,
    unfinished: usize,
    /// Requests whose emitted token count drifted from the workload's
    /// ground-truth decode length — must be zero in every cell.
    token_violations: usize,
    chaos_quiet: bool,
}

fn run_chaos_cell(
    stressor: Stressor,
    scaler: ScalerKind,
    n_peak: usize,
    requests: usize,
) -> ChaosCellResult {
    let mut cfg = SimConfig {
        trace: TraceKind::ShareGpt,
        mode: ServingMode::Colocated,
        policy: Policy::PolyServe,
        instances: n_peak,
        requests,
        rate_frac_of_optimal: 0.6,
        diurnal: (stressor != Stressor::FlashCrowd)
            .then_some(DiurnalSpec { peak_to_trough: 3.0, period_s: 600.0 }),
        ..Default::default()
    };
    cfg.elastic.scaler = scaler;
    cfg.elastic.provision_delay_ms = 3_000;
    cfg.elastic.scale_eval_ms = 1_000;
    cfg.elastic.migration = true;
    cfg.elastic.min_instances = (n_peak / 4).max(2);
    cfg.elastic.max_instances = n_peak * 2;
    match stressor {
        Stressor::Failure => {
            // Aggressive MTBF so even the smoke span sees failures.
            cfg.chaos.fail_mtbf_s = 3.0;
        }
        Stressor::SpotPreempt => {
            // Wait-drain against a 1 s grace on stretched decode tails:
            // a preempted spot server holding a long-output resident
            // cannot drain in time, so the hard deadline fires — the
            // kill path the smoke gate asserts on.
            cfg.elastic.migration = false;
            cfg.chaos.preempt_mtbf_s = 4.0;
            cfg.chaos.preempt_grace_ms = 1_000;
            cfg.chaos.spot_fraction = 1.0;
            cfg.chaos.spot_price_frac = 0.3;
        }
        Stressor::FlashCrowd => {}
    }
    let mut exp = Experiment::prepare(&cfg);
    if stressor == Stressor::SpotPreempt {
        stretch_decode_tail(&mut exp.workload);
    }
    if stressor == Stressor::FlashCrowd {
        let base = exp.rate_rps;
        exp.override_arrivals(&RateSchedule::flash_crowd(base, 4.0, 10_000, 20_000, 10));
    }
    // Ground truth *after* every workload mutation: conservation means
    // each request emits exactly its (possibly stretched) decode_len.
    let decode_len: HashMap<u64, u32> =
        exp.workload.requests.iter().map(|r| (r.id, r.decode_len)).collect();
    let res = exp.run();
    let token_violations = res
        .outcomes
        .iter()
        .filter(|o| o.tokens != decode_len[&o.id] as u64)
        .count();
    ChaosCellResult {
        attain: res.attainment.overall(),
        bill_s: res.cost.discounted_bill_ms(cfg.chaos.spot_price_frac) / 1000.0,
        cost_per_1k_goodput_tokens: res.cost.cost_per_1k_goodput_tokens_s(),
        failures: res.chaos.failures,
        preempt_notices: res.chaos.preempt_notices,
        preempt_drained: res.chaos.preempt_drained,
        deadline_kills: res.chaos.preempt_deadline_kills,
        replaced_requests: res.chaos.replaced_requests,
        lost_kv_tokens: res.chaos.lost_kv_tokens,
        spot_s: res.cost.spot_instance_ms as f64 / 1000.0,
        unfinished: res.unfinished,
        token_violations,
        chaos_quiet: res.chaos.is_quiet(),
    }
}

/// One recovery cell: correlated rack/zone kills against the PR 10
/// recovery layer, with the KV-checkpoint sweep and the chaos-adaptive
/// predictive scaler toggled independently.
struct RecoveryCellResult {
    attain: f64,
    bill_s: f64,
    failures: u64,
    domain_kills: u64,
    checkpoints: u64,
    checkpoint_tokens: u64,
    checkpoint_cost_ms: u64,
    recovered_kv_tokens: u64,
    reprefill_tokens: u64,
    lost_kv_tokens: u64,
    replaced_requests: u64,
    unfinished: usize,
    token_violations: usize,
}

/// Correlated-kill recovery cell: a 2-zone × 2-rack fleet stripe under
/// an aggressive domain-kill MTBF process (one draw fails a whole rack
/// — or occasionally a zone — at once), served by the predictive
/// scaler with migration on so replacements land and victims re-place
/// away from the blast radius. `checkpoint` turns the periodic KV
/// snapshot sweep on (suffix-only re-prefill after a kill), `adaptive`
/// lets the scaler consume `ChaosStats` online (churn padding + the
/// spot/on-demand policy flip). All three cells share one workload
/// seed, so their ledgers compare like-for-like.
fn run_recovery_cell(
    checkpoint: bool,
    adaptive: bool,
    n_peak: usize,
    requests: usize,
) -> RecoveryCellResult {
    let mut cfg = SimConfig {
        trace: TraceKind::ShareGpt,
        mode: ServingMode::Colocated,
        policy: Policy::PolyServe,
        instances: n_peak,
        requests,
        rate_frac_of_optimal: 0.6,
        diurnal: Some(DiurnalSpec { peak_to_trough: 3.0, period_s: 600.0 }),
        ..Default::default()
    };
    cfg.elastic.scaler = ScalerKind::Predictive;
    cfg.elastic.provision_delay_ms = 3_000;
    cfg.elastic.scale_eval_ms = 1_000;
    cfg.elastic.migration = true;
    cfg.elastic.min_instances = (n_peak / 4).max(2);
    cfg.elastic.max_instances = n_peak * 2;
    cfg.chaos.zones = 2;
    cfg.chaos.racks_per_zone = 2;
    cfg.chaos.domain_fail_mtbf_s = 8.0;
    cfg.chaos.checkpoint_period_ms = if checkpoint { 500 } else { 0 };
    cfg.chaos.adaptive = adaptive;
    // Half the elastic replacements land on spot so the adaptive cell
    // exercises the churn-vs-discount policy flip too.
    cfg.chaos.spot_fraction = 0.5;
    cfg.chaos.spot_price_frac = 0.4;
    let exp = Experiment::prepare(&cfg);
    let decode_len: HashMap<u64, u32> =
        exp.workload.requests.iter().map(|r| (r.id, r.decode_len)).collect();
    let res = exp.run();
    let token_violations = res
        .outcomes
        .iter()
        .filter(|o| o.tokens != decode_len[&o.id] as u64)
        .count();
    RecoveryCellResult {
        attain: res.attainment.overall(),
        bill_s: res.cost.discounted_bill_ms(cfg.chaos.spot_price_frac) / 1000.0,
        failures: res.chaos.failures,
        domain_kills: res.chaos.domain_kills,
        checkpoints: res.chaos.checkpoints,
        checkpoint_tokens: res.chaos.checkpoint_tokens,
        checkpoint_cost_ms: res.chaos.checkpoint_cost_ms,
        recovered_kv_tokens: res.chaos.recovered_kv_tokens,
        reprefill_tokens: res.chaos.reprefill_tokens,
        lost_kv_tokens: res.chaos.lost_kv_tokens,
        replaced_requests: res.chaos.replaced_requests,
        unfinished: res.unfinished,
        token_violations,
    }
}

/// The queue-discipline × admission-control axis of the overload grid.
#[derive(Clone, Copy, PartialEq)]
enum OverloadPolicy {
    /// Pre-EDF reference: FIFO pending queues, no gate, no retries.
    Fifo,
    /// Deadline-ordered pending queues only.
    Edf,
    /// EDF + SLO-feasibility admission control at the arrival edge.
    EdfReject,
    /// EDF + admission control + retry-with-backoff clients.
    EdfRejectRetry,
}

impl OverloadPolicy {
    const ALL: [OverloadPolicy; 4] = [
        OverloadPolicy::Fifo,
        OverloadPolicy::Edf,
        OverloadPolicy::EdfReject,
        OverloadPolicy::EdfRejectRetry,
    ];

    fn name(self) -> &'static str {
        match self {
            OverloadPolicy::Fifo => "fifo",
            OverloadPolicy::Edf => "edf",
            OverloadPolicy::EdfReject => "edf+reject",
            OverloadPolicy::EdfRejectRetry => "edf+reject+retry",
        }
    }

    fn reject(self) -> bool {
        matches!(self, OverloadPolicy::EdfReject | OverloadPolicy::EdfRejectRetry)
    }
}

struct OverloadCellResult {
    /// Fraction of all arrivals terminally shed.
    rejection_rate: f64,
    /// DSLO attainment among *accepted* requests (== overall attainment
    /// for the gate-free policies, which accept everything).
    accepted_attain: f64,
    /// Accepted requests that finished but missed their SLO — the
    /// reject-mode smoke gate demands zero.
    accepted_violations: usize,
    p99_ttft_ms: f64,
    goodput_tokens: u64,
    goodput_tok_per_s: f64,
    shed_tokens: u64,
    retries: u64,
    /// Requests admitted on a backoff re-arrival.
    retry_admitted: u64,
    retry_exhausted: u64,
    aged_past_patience: u64,
    max_pend_ms: u64,
    unfinished: usize,
}

/// One overload cell: colocated fleet prepared at peak capacity so
/// `rate_frac` is a true multiple of the fleet's optimal goodput, then
/// run elastic from the floor under the given queue/admission policy.
fn run_overload_cell(
    policy: OverloadPolicy,
    scaler: ScalerKind,
    rate_frac: f64,
    n_peak: usize,
    requests: usize,
) -> OverloadCellResult {
    let cfg = SimConfig {
        trace: TraceKind::ShareGpt,
        mode: ServingMode::Colocated,
        policy: Policy::PolyServe,
        instances: n_peak,
        requests,
        rate_frac_of_optimal: rate_frac,
        ..Default::default()
    };
    // Prepare against the peak fleet (pins the arrival stream every
    // policy faces at this saturation multiple), then retune the
    // cluster config on the shared Experiment — the run_cell pattern.
    let mut exp = Experiment::prepare(&cfg);
    let cfg = &mut exp.cfg;
    cfg.elastic.scaler = scaler;
    cfg.elastic.provision_delay_ms = 3_000;
    cfg.elastic.scale_eval_ms = 1_000;
    cfg.elastic.migration = true;
    cfg.elastic.min_instances = (n_peak / 4).max(2);
    cfg.elastic.max_instances = n_peak;
    cfg.instances = cfg.elastic.min_instances;
    cfg.overload.enabled = true;
    cfg.overload.reject = policy.reject();
    cfg.overload.retry = policy == OverloadPolicy::EdfRejectRetry;
    cfg.overload.retry_base_ms = 500;
    cfg.overload.retry_max_attempts = 3;
    exp.fifo_reference = policy == OverloadPolicy::Fifo;
    let res = exp.run();
    let accepted_violations = res
        .outcomes
        .iter()
        .filter(|o| !o.rejected && o.finish_ms.is_some() && !o.attained)
        .count();
    let (ttft, _) = polyserve::metrics::latency_summary(&res.outcomes);
    let span_s = (res.sim_span_ms as f64 / 1000.0).max(1e-9);
    OverloadCellResult {
        rejection_rate: res.overload.rejection_rate(res.outcomes.len() as u64),
        accepted_attain: res.attainment.overall(),
        accepted_violations,
        p99_ttft_ms: ttft.map(|s| s.p99()).unwrap_or(f64::NAN),
        goodput_tokens: res.cost.goodput_tokens,
        goodput_tok_per_s: res.cost.goodput_tokens as f64 / span_s,
        shed_tokens: res.overload.shed_tokens,
        retries: res.overload.retries,
        retry_admitted: res.overload.retry_histogram.iter().sum(),
        retry_exhausted: res.overload.retry_exhausted,
        aged_past_patience: res.overload.aged_past_patience,
        max_pend_ms: res.overload.max_pend_ms,
        unfinished: res.unfinished,
    }
}

fn main() {
    let mut bench = Bench::new("elastic_scaling");
    let full = full_scale();
    let smoke = smoke_scale();
    let requests = if full {
        30_000
    } else if smoke {
        800
    } else {
        4_000
    };
    let n_peak = if full {
        48
    } else if smoke {
        8
    } else {
        24
    };
    let threads = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4);

    let mut cells = Vec::new();
    for scenario in SCENARIOS {
        for mode in [ServingMode::Colocated, ServingMode::PdDisaggregated] {
            cells.push(Cell {
                scenario,
                mode,
                scaler: ScalerKind::Off,
                migration: false,
                prefill_elastic: false,
                is_static: true,
            });
            for scaler in [ScalerKind::Gradient, ScalerKind::Threshold, ScalerKind::Predictive] {
                for migration in [false, true] {
                    cells.push(Cell {
                        scenario,
                        mode,
                        scaler,
                        migration,
                        prefill_elastic: false,
                        is_static: false,
                    });
                }
            }
            // Elastic-prefill rows (PD only): TTFT pressure scales the
            // prefill tier too; migration on so drained prefill queues
            // re-route instead of wait.
            if mode == ServingMode::PdDisaggregated {
                for scaler in [ScalerKind::Gradient, ScalerKind::Predictive] {
                    cells.push(Cell {
                        scenario,
                        mode,
                        scaler,
                        migration: true,
                        prefill_elastic: true,
                        is_static: false,
                    });
                }
            }
        }
    }
    let results = par_map(cells, threads, move |_, c| {
        let r = run_cell(&c, n_peak, requests);
        (c, r)
    });

    // Index static baselines for the savings column: (bill, attain).
    let static_cell = |scenario: &str, mode: ServingMode| {
        results
            .iter()
            .find(|(c, _)| c.is_static && c.scenario.name == scenario && c.mode == mode)
            .map(|(_, r)| (r.active_instance_s, r.attain))
            .unwrap_or((f64::NAN, f64::NAN))
    };

    let mut rows = Vec::new();
    for (c, r) in &results {
        let policy = if c.is_static {
            "static".to_string()
        } else {
            format!(
                "{}{}{}",
                c.scaler.name(),
                if c.migration { "+mig" } else { "" },
                if c.prefill_elastic { "+pf" } else { "" },
            )
        };
        let (base_bill, base_attain) = static_cell(c.scenario.name, c.mode);
        let savings = if c.is_static { 0.0 } else { 1.0 - r.active_instance_s / base_bill };
        let d_attain = r.attain - base_attain;
        rows.push(vec![
            c.scenario.name.to_string(),
            c.mode.name().to_string(),
            policy,
            f(r.attain, 3),
            f(d_attain, 3),
            f(r.active_instance_s, 1),
            f(savings, 3),
            f(r.cost_per_1k_goodput_tokens, 3),
            f(r.fleet_mean, 1),
            r.fleet_peak.to_string(),
            r.fleet_trough.to_string(),
            f(r.pf_mean, 1),
            r.pf_peak.to_string(),
            r.pf_trough.to_string(),
            r.drains.to_string(),
            f(r.drain_mean_ms, 0),
            r.migrated_reqs.to_string(),
            r.migrated_prefill_jobs.to_string(),
            r.unfinished.to_string(),
        ]);
    }
    bench.table(
        "Elastic scaling: active-instance-seconds and drain latency vs static fleet at equal peak capacity",
        &[
            "scenario",
            "mode",
            "policy",
            "attain",
            "d_attain_vs_static",
            "active_inst_s",
            "savings_vs_static",
            "cost_per_1k_goodput_tok",
            "fleet_mean",
            "fleet_peak",
            "fleet_trough",
            "pf_mean",
            "pf_peak",
            "pf_trough",
            "drains",
            "drain_mean_ms",
            "migrated_reqs",
            "migrated_pf",
            "unfinished",
        ],
        &rows,
    );

    // Multi-model cells: the built-in two-model registry under the same
    // elastic machinery — a steady 70/30 diurnal mix, and a model-1
    // flash crowd engineered so the mix planner must hot-swap weights.
    let model_cells = [("model_mix_diurnal", false), ("model_hot_swap_flash", true)]
        .map(|(name, fc)| (name, run_model_cell(n_peak, requests, fc)));
    let model_rows: Vec<Vec<String>> = model_cells
        .iter()
        .map(|(name, r)| {
            vec![
                name.to_string(),
                f(r.attain[0], 3),
                f(r.attain[1], 3),
                r.served[0].to_string(),
                r.served[1].to_string(),
                f(r.bill_s[0], 1),
                f(r.bill_s[1], 1),
                f(r.fleet_mean[0], 1),
                f(r.fleet_mean[1], 1),
                r.swaps.to_string(),
                r.unfinished.to_string(),
            ]
        })
        .collect();
    bench.table(
        "Multi-model fleet: per-model attainment, bill and fleet share (built-in 8B + 32B pair)",
        &[
            "cell",
            "attain_m0",
            "attain_m1",
            "served_m0",
            "served_m1",
            "bill_m0_s",
            "bill_m1_s",
            "fleet_m0_mean",
            "fleet_m1_mean",
            "model_swaps",
            "unfinished",
        ],
        &model_rows,
    );

    // Adversarial cells: cost × attainment for each scaling policy
    // under instance failures, spot preemption, and a flash crowd.
    let mut chaos_grid = Vec::new();
    for stressor in [Stressor::Failure, Stressor::SpotPreempt, Stressor::FlashCrowd] {
        for scaler in [ScalerKind::Gradient, ScalerKind::Threshold, ScalerKind::Predictive] {
            chaos_grid.push((stressor, scaler));
        }
    }
    let chaos_results = par_map(chaos_grid, threads, move |_, (stressor, scaler)| {
        (stressor, scaler, run_chaos_cell(stressor, scaler, n_peak, requests))
    });
    let chaos_rows: Vec<Vec<String>> = chaos_results
        .iter()
        .map(|(stressor, scaler, r)| {
            vec![
                stressor.name().to_string(),
                scaler.name().to_string(),
                f(r.attain, 3),
                f(r.bill_s, 1),
                f(r.cost_per_1k_goodput_tokens, 3),
                r.failures.to_string(),
                r.preempt_notices.to_string(),
                r.preempt_drained.to_string(),
                r.deadline_kills.to_string(),
                r.replaced_requests.to_string(),
                r.lost_kv_tokens.to_string(),
                f(r.spot_s, 1),
                r.token_violations.to_string(),
                r.unfinished.to_string(),
            ]
        })
        .collect();
    bench.table(
        "Chaos: cost (spot-discounted) x attainment under instance failures, spot preemption, and a flash crowd",
        &[
            "stressor",
            "scaler",
            "attain",
            "bill_s",
            "cost_per_1k_goodput_tok",
            "failures",
            "preempts",
            "drained",
            "deadline_kills",
            "replaced",
            "lost_kv_tok",
            "spot_s",
            "token_violations",
            "unfinished",
        ],
        &chaos_rows,
    );

    // Recovery cells: correlated rack/zone kills × {bare, +checkpoint,
    // +checkpoint+adaptive} — the PR 10 failure-domain / KV-snapshot /
    // chaos-adaptive-provisioning ledger on one shared workload.
    let recovery_cells: Vec<(&str, bool, bool)> = vec![
        ("rack_kill", false, false),
        ("rack_kill+ckpt", true, false),
        ("rack_kill+ckpt+adaptive", true, true),
    ];
    let recovery_results = par_map(recovery_cells, threads, move |_, (name, ckpt, adaptive)| {
        (name, run_recovery_cell(ckpt, adaptive, n_peak, requests))
    });
    let recovery_rows: Vec<Vec<String>> = recovery_results
        .iter()
        .map(|(name, r)| {
            vec![
                name.to_string(),
                f(r.attain, 3),
                f(r.bill_s, 1),
                r.domain_kills.to_string(),
                r.failures.to_string(),
                r.replaced_requests.to_string(),
                r.checkpoints.to_string(),
                r.checkpoint_tokens.to_string(),
                r.checkpoint_cost_ms.to_string(),
                r.recovered_kv_tokens.to_string(),
                r.reprefill_tokens.to_string(),
                r.lost_kv_tokens.to_string(),
                r.token_violations.to_string(),
                r.unfinished.to_string(),
            ]
        })
        .collect();
    bench.table(
        "Recovery: correlated rack/zone kills x KV checkpointing x chaos-adaptive provisioning",
        &[
            "cell",
            "attain",
            "bill_s",
            "domain_kills",
            "failures",
            "replaced",
            "checkpoints",
            "ckpt_tok",
            "ckpt_cost_ms",
            "recovered_kv_tok",
            "reprefill_tok",
            "lost_kv_tok",
            "token_violations",
            "unfinished",
        ],
        &recovery_rows,
    );

    // Overload grid: arrival rate from half to 3× the peak fleet's
    // optimal goodput × queue/admission policy × scaler — the
    // rejection-rate × tail-attainment × goodput curves.
    let rates: &[f64] = if full {
        &[0.5, 1.0, 1.5, 2.0, 3.0]
    } else if smoke {
        &[0.5, 2.0]
    } else {
        &[0.5, 1.0, 2.0, 3.0]
    };
    let mut ol_grid = Vec::new();
    for &rate in rates {
        for scaler in [ScalerKind::Gradient, ScalerKind::Threshold, ScalerKind::Predictive] {
            for policy in OverloadPolicy::ALL {
                ol_grid.push((rate, scaler, policy));
            }
        }
    }
    let ol_results = par_map(ol_grid, threads, move |_, (rate, scaler, policy)| {
        (rate, scaler, policy, run_overload_cell(policy, scaler, rate, n_peak, requests))
    });
    let ol_rows: Vec<Vec<String>> = ol_results
        .iter()
        .map(|(rate, scaler, policy, r)| {
            vec![
                f(*rate, 2),
                scaler.name().to_string(),
                policy.name().to_string(),
                f(r.rejection_rate, 3),
                f(r.accepted_attain, 3),
                r.accepted_violations.to_string(),
                f(r.p99_ttft_ms, 0),
                r.goodput_tokens.to_string(),
                f(r.goodput_tok_per_s, 0),
                r.shed_tokens.to_string(),
                r.retries.to_string(),
                r.retry_admitted.to_string(),
                r.retry_exhausted.to_string(),
                r.aged_past_patience.to_string(),
                r.max_pend_ms.to_string(),
                r.unfinished.to_string(),
            ]
        })
        .collect();
    bench.table(
        "Overload: rejection-rate x tail-attainment x goodput past saturation (queue/admission policy x scaler)",
        &[
            "rate_x_optimal",
            "scaler",
            "policy",
            "rejection_rate",
            "attain_accepted",
            "accepted_violations",
            "p99_ttft_ms",
            "goodput_tok",
            "goodput_tok_per_s",
            "shed_tok",
            "retries",
            "retry_admitted",
            "retry_exhausted",
            "aged_past_patience",
            "max_pend_ms",
            "unfinished",
        ],
        &ol_rows,
    );

    // Smoke invariants (CI): every request must finish in every cell
    // (the predictive cells included), migration counters move only
    // when migration is on, and the prefill fleet moves only in `+pf`
    // cells.
    if smoke {
        assert!(
            results
                .iter()
                .any(|(c, _)| c.scaler == ScalerKind::Predictive && !c.is_static),
            "smoke gate must cover the predictive policy"
        );
        for (c, r) in &results {
            assert_eq!(
                r.unfinished, 0,
                "{}/{}/{:?} mig={} pf={} left requests unfinished",
                c.scenario.name,
                c.mode.name(),
                c.scaler,
                c.migration,
                c.prefill_elastic
            );
            assert!((0.0..=1.0).contains(&r.attain));
            if !c.migration {
                assert_eq!(
                    r.migrated_reqs, 0,
                    "{}/{}/{:?}: migration off but requests migrated",
                    c.scenario.name,
                    c.mode.name(),
                    c.scaler
                );
                assert_eq!(r.migrated_kv_tokens, 0);
            }
            if !c.prefill_elastic {
                assert_eq!(
                    r.migrated_prefill_jobs, 0,
                    "{}/{}/{:?}: static prefill tier but prefill jobs migrated",
                    c.scenario.name,
                    c.mode.name(),
                    c.scaler
                );
                assert_eq!(
                    r.pf_peak, r.pf_trough,
                    "{}/{}/{:?}: static prefill tier changed size",
                    c.scenario.name,
                    c.mode.name(),
                    c.scaler
                );
            }
        }
        // Multi-model gates: both models keep serving and billing in
        // both cells, per-model fleet series exist, and the flash crowd
        // forces at least one weight hot-swap. The printed marker line
        // is grep-gated in CI so these asserts can't silently vanish.
        for (name, r) in &model_cells {
            assert_eq!(r.unfinished, 0, "{name}: model-mix cell left requests unfinished");
            assert!(
                r.served[0] > 0 && r.served[1] > 0,
                "{name}: both registry models must serve traffic"
            );
            assert!(
                r.bill_s[0] > 0.0 && r.bill_s[1] > 0.0,
                "{name}: both registry models must accrue active-instance bill"
            );
            assert!(
                r.fleet_mean[0] > 0.0,
                "{name}: per-model fleet series missing for model 0"
            );
        }
        let (_, flash) = &model_cells[1];
        assert!(
            flash.swaps >= 1,
            "flash crowd must force at least one enforced model hot-swap"
        );
        println!("model-mix smoke OK: {} model hot-swaps enforced", flash.swaps);
        // Chaos gates: every cell conserves tokens exactly and finishes
        // everything; the failure cells actually fail instances, the
        // spot cells actually issue notices and at least one hard
        // deadline kill lands, and the flash crowd runs chaos-quiet.
        for (stressor, scaler, r) in &chaos_results {
            let label = format!("{}/{}", stressor.name(), scaler.name());
            assert_eq!(r.unfinished, 0, "{label}: chaos cell left requests unfinished");
            assert_eq!(
                r.token_violations, 0,
                "{label}: per-request token conservation violated"
            );
            assert!((0.0..=1.0).contains(&r.attain), "{label}");
            match stressor {
                Stressor::Failure => {
                    assert!(r.failures >= 1, "{label}: no instance failure injected");
                    assert!(
                        r.replaced_requests >= 1 || r.lost_kv_tokens == 0,
                        "{label}: failures lost KV without replacing anyone"
                    );
                }
                Stressor::SpotPreempt => {
                    assert!(r.preempt_notices >= 1, "{label}: no preemption notice fired");
                    assert!(r.spot_s > 0.0, "{label}: no spot instance ever billed");
                }
                Stressor::FlashCrowd => {
                    assert!(r.chaos_quiet, "{label}: flash crowd must run chaos-quiet");
                }
            }
        }
        let kills: u64 = chaos_results
            .iter()
            .filter(|(s, _, _)| *s == Stressor::SpotPreempt)
            .map(|(_, _, r)| r.deadline_kills)
            .sum();
        assert!(kills >= 1, "no spot preemption ever hit its hard deadline");
        let failures: u64 = chaos_results.iter().map(|(_, _, r)| r.failures).sum();
        println!(
            "chaos smoke OK: {failures} failures, {kills} deadline kills, 0 token violations"
        );
        // Recovery gates: every cell conserves tokens exactly under
        // correlated kills; the checkpoint cell actually snapshots,
        // bills the transfer, restores KV on failure and loses strictly
        // fewer KV tokens than the bare cell; the chaos-adaptive cell
        // holds attainment (small slack for placement reordering noise
        // — padding can only add capacity).
        let rec = |name: &str| {
            recovery_results
                .iter()
                .find(|(n, _)| *n == name)
                .map(|(_, r)| r)
                .expect("recovery cell missing")
        };
        for (name, r) in &recovery_results {
            assert_eq!(r.unfinished, 0, "{name}: recovery cell left requests unfinished");
            assert_eq!(r.token_violations, 0, "{name}: token conservation violated");
            assert!(r.domain_kills >= 1, "{name}: no correlated kill ever fired");
            assert!(r.failures >= r.domain_kills, "{name}: a domain kill fails >= 1 instance");
        }
        let bare = rec("rack_kill");
        let ckpt = rec("rack_kill+ckpt");
        let adaptive = rec("rack_kill+ckpt+adaptive");
        assert_eq!(bare.checkpoints, 0, "checkpointing off must never snapshot");
        assert!(ckpt.checkpoints >= 1, "the checkpoint sweep never fired");
        assert!(ckpt.checkpoint_cost_ms >= 1, "snapshot transfer must be billed");
        assert!(
            ckpt.recovered_kv_tokens >= 1,
            "kills under a live sweep must restore some KV"
        );
        assert!(
            ckpt.lost_kv_tokens < bare.lost_kv_tokens,
            "checkpointing must strictly reduce lost KV: {} vs bare {}",
            ckpt.lost_kv_tokens,
            bare.lost_kv_tokens,
        );
        assert!(
            adaptive.attain >= ckpt.attain - 0.01,
            "chaos-adaptive provisioning worsened attainment under correlated kills: \
             {:.3} vs {:.3}",
            adaptive.attain,
            ckpt.attain,
        );
        println!(
            "recovery smoke OK: {} domain kills, {} KV tokens restored (lost {} -> {}), \
             adaptive attain {:.3} vs {:.3}",
            bare.domain_kills + ckpt.domain_kills + adaptive.domain_kills,
            ckpt.recovered_kv_tokens,
            bare.lost_kv_tokens,
            ckpt.lost_kv_tokens,
            adaptive.attain,
            ckpt.attain,
        );
        // Overload gates at 2× saturation, per scaler: the reject cells
        // actually shed, accepted requests never miss their SLO in
        // reject mode, EDF never worsens the FIFO TTFT tail (small
        // slack for reordering noise), and edf+reject strictly beats
        // FIFO on accepted-request attainment.
        let ol_cell = |rate: f64, scaler: ScalerKind, policy: OverloadPolicy| {
            ol_results
                .iter()
                .find(|(rt, s, p, _)| (rt - rate).abs() < 1e-9 && *s == scaler && *p == policy)
                .map(|(_, _, _, r)| r)
                .expect("overload grid cell missing")
        };
        let mut shed_at_2x = 0u64;
        for scaler in [ScalerKind::Gradient, ScalerKind::Threshold, ScalerKind::Predictive] {
            let fifo = ol_cell(2.0, scaler, OverloadPolicy::Fifo);
            let edf = ol_cell(2.0, scaler, OverloadPolicy::Edf);
            let rej = ol_cell(2.0, scaler, OverloadPolicy::EdfReject);
            let rr = ol_cell(2.0, scaler, OverloadPolicy::EdfRejectRetry);
            for (p, r) in
                [("fifo", fifo), ("edf", edf), ("edf+reject", rej), ("edf+reject+retry", rr)]
            {
                assert_eq!(
                    r.unfinished, 0,
                    "{}/{p}: overload cell left requests unfinished",
                    scaler.name()
                );
            }
            assert!(
                rej.rejection_rate > 0.0 && rr.rejection_rate > 0.0,
                "{}: no rejections at 2x saturation (reject {:.3}, retry {:.3})",
                scaler.name(),
                rej.rejection_rate,
                rr.rejection_rate,
            );
            assert_eq!(
                rej.accepted_violations, 0,
                "{}: admitted requests missed their SLO in reject mode",
                scaler.name()
            );
            assert!(
                edf.p99_ttft_ms <= fifo.p99_ttft_ms * 1.10 + 5.0,
                "{}: EDF worsened the FIFO TTFT tail at 2x: {:.0} ms vs {:.0} ms",
                scaler.name(),
                edf.p99_ttft_ms,
                fifo.p99_ttft_ms,
            );
            assert!(
                rej.accepted_attain > fifo.accepted_attain,
                "{}: edf+reject accepted attainment {:.3} must strictly beat fifo {:.3} at 2x",
                scaler.name(),
                rej.accepted_attain,
                fifo.accepted_attain,
            );
            shed_at_2x += (rej.rejection_rate * requests as f64) as u64;
        }
        println!(
            "overload smoke OK: {shed_at_2x} rejections at 2x saturation, 0 accepted-SLO violations, fifo->edf tail non-increasing"
        );
        println!("smoke invariants OK ({} cells)", results.len());
    }
    bench.finish();
}
