//! Table 1 reproduction: input/output length percentiles of every trace
//! generator vs the paper's published values.

use polyserve::util::benchkit::{f, full_scale, Bench};
use polyserve::util::rng::Rng;
use polyserve::util::stats::Summary;
use polyserve::workload::{TraceGenerator, TraceKind};

/// The paper's Table 1 (input, output) percentile rows [p25..p99].
fn paper_row(kind: TraceKind) -> Option<([f64; 6], [f64; 6])> {
    Some(match kind {
        TraceKind::Uniform4096x1024 => (
            [2047., 4093., 6149., 7377., 7785., 8108.],
            [510., 1023., 1535., 1843., 1944., 2027.],
        ),
        TraceKind::Uniform512x512 => (
            [255., 511., 768., 921., 973., 1013.],
            [256., 511., 768., 922., 973., 1014.],
        ),
        TraceKind::MooncakeConversation => (
            [2320., 6923., 15400., 27571., 39583., 85401.],
            [159., 350., 472., 597., 698., 1136.],
        ),
        TraceKind::MooncakeSynthetic => (
            [277., 11587., 23286., 38737., 49009., 66458.],
            [10., 68., 250., 390., 522., 768.],
        ),
        TraceKind::MooncakeToolagent => (
            [3228., 6346., 7468., 16818., 26175., 61824.],
            [12., 30., 355., 506., 600., 890.],
        ),
        TraceKind::Lmsys => (
            [12., 28., 82., 301., 430., 750.],
            [39., 140., 338., 512., 519., 853.],
        ),
        TraceKind::ShareGpt => (
            [16., 36., 158., 818., 1613., 3421.],
            [131., 280., 445., 682., 846., 1001.],
        ),
        TraceKind::Splitwise => (
            [396., 1019., 1186., 2735., 4083., 4142.],
            [85., 130., 395., 425., 451., 601.],
        ),
    })
}

fn main() {
    let mut bench = Bench::new("table1");
    // §5.1 samples 300k requests per dataset; scaled default 50k.
    let n = if full_scale() { 300_000 } else { 50_000 };
    let headers = ["trace", "axis", "p25", "p50", "p75", "p90", "p95", "p99", "max|err|%"];
    let mut rows = Vec::new();
    for kind in TraceKind::ALL {
        let gen = TraceGenerator::new(kind);
        let mut rng = Rng::new(0x7AB1E);
        let mut ins = Vec::with_capacity(n);
        let mut outs = Vec::with_capacity(n);
        for _ in 0..n {
            let (p, d) = gen.sample_lengths(&mut rng);
            ins.push(p as f64);
            outs.push(d as f64);
        }
        let (want_in, want_out) = paper_row(kind).unwrap();
        for (axis, xs, want) in [("input", &ins, want_in), ("output", &outs, want_out)] {
            let s = Summary::of(xs);
            let mut max_err: f64 = 0.0;
            let mut row = vec![kind.name().to_string(), axis.to_string()];
            for (got, want) in s.percentiles.iter().zip(&want) {
                row.push(f(*got, 0));
                max_err = max_err.max(100.0 * (got - want).abs() / want.max(1.0));
            }
            row.push(f(max_err, 1));
            rows.push(row);
        }
    }
    bench.table("Table 1: trace length percentiles (vs paper)", &headers, &rows);
    bench.finish();
}
