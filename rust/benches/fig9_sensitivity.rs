//! Fig 9 reproduction: per-instance goodput as the fleet grows from 8
//! to 64 instances (uniform_4096_1024 trace) — per-instance goodput
//! rises with scale as tier fragmentation amortizes.
//!
//! The (mode × policy × fleet size) grid fans out via `par_map` (each
//! cell sweeps its rate fractions serially inside one worker);
//! `par_map` preserves input order, so the rows print
//! deterministically.

use polyserve::analysis::ServingMode;
use polyserve::config::{Policy, SimConfig};
use polyserve::figures::attainment_curve;
use polyserve::util::benchkit::{f, full_scale, Bench};
use polyserve::util::threadpool::par_map;
use polyserve::workload::TraceKind;

fn main() {
    let mut bench = Bench::new("fig9");
    let requests = if full_scale() { 30_000 } else { 4_000 };
    let sizes = [8usize, 16, 24, 32, 40, 48, 56, 64];
    let fracs = [0.6, 0.8, 1.0, 1.2, 1.4, 1.6];
    let threads = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4);

    let mut cells: Vec<(ServingMode, Policy, usize)> = Vec::new();
    for mode in [ServingMode::PdDisaggregated, ServingMode::Colocated] {
        for policy in [Policy::PolyServe, Policy::Minimal] {
            for &n in &sizes {
                cells.push((mode, policy, n));
            }
        }
    }
    let rows = par_map(cells, threads, move |_, (mode, policy, n)| {
        let cfg = SimConfig {
            trace: TraceKind::Uniform4096x1024,
            mode,
            policy,
            instances: n,
            requests,
            ..Default::default()
        };
        // Inner sweep serial: the outer fan-out already saturates the
        // pool.
        let (curve, _opt) = attainment_curve(&cfg, &fracs, 1);
        let g = curve.goodput_at(0.9).unwrap_or(0.0);
        vec![
            mode.name().into(),
            policy.label(mode),
            n.to_string(),
            f(g, 2),
            f(g / n as f64, 3),
        ]
    });
    bench.table(
        "Fig 9: per-instance goodput vs fleet size (uniform_4096_1024)",
        &["mode", "policy", "instances", "goodput_rps", "per_instance_rps"],
        &rows,
    );
    bench.finish();
}
