//! Ablations: flip each PolyServe mechanism (§4) off individually and
//! measure goodput@90% — quantifies what each design choice buys.

use polyserve::analysis::ServingMode;
use polyserve::config::{Features, Policy, SimConfig};
use polyserve::figures::attainment_curve;
use polyserve::util::benchkit::{f, full_scale, Bench};
use polyserve::workload::TraceKind;

fn main() {
    let mut bench = Bench::new("ablations");
    let requests = if full_scale() { 30_000 } else { 8_000 };
    let fracs = [0.7, 0.9, 1.05, 1.2, 1.35, 1.5, 1.7];
    let threads = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4);

    let variants: Vec<(&str, Box<dyn Fn(&mut Features)>)> = vec![
        ("full PolyServe", Box::new(|_f: &mut Features| {})),
        ("no load gradient (least-loaded)", Box::new(|f| f.load_gradient = false)),
        ("no lazy promotion", Box::new(|f| f.lazy_promotion = false)),
        (
            "eager promotion",
            Box::new(|f| {
                f.lazy_promotion = false;
                f.eager_promotion = true;
            }),
        ),
        ("no wait-time awareness", Box::new(|f| f.wait_time_aware = false)),
        ("no dynamic chunking", Box::new(|f| f.dynamic_chunking = false)),
        (
            "no continuous chunk prediction",
            Box::new(|f| f.continuous_chunk_prediction = false),
        ),
    ];

    let mut rows = Vec::new();
    for mode in [ServingMode::PdDisaggregated, ServingMode::Colocated] {
        for (name, tweak) in &variants {
            let mut cfg = SimConfig {
                trace: TraceKind::ShareGpt,
                mode,
                policy: Policy::PolyServe,
                requests,
                ..Default::default()
            };
            tweak(&mut cfg.features);
            if cfg.validate().is_err() {
                continue;
            }
            let (curve, opt) = attainment_curve(&cfg, &fracs, threads);
            let g = curve.goodput_at(0.9).unwrap_or(0.0);
            rows.push(vec![
                mode.name().into(),
                name.to_string(),
                f(g, 1),
                f(100.0 * g / opt.max(1e-9), 1),
            ]);
        }
    }
    bench.table(
        "Ablations: goodput@90% per disabled mechanism (sharegpt, 20 inst)",
        &["mode", "variant", "goodput_rps", "%of_optimal"],
        &rows,
    );
    bench.finish();
}
