//! Ablations: flip each PolyServe mechanism (§4) off individually and
//! measure goodput@90% — quantifies what each design choice buys.
//!
//! The (mode × variant) grid fans out across the thread pool via
//! `par_map` (each cell sweeps its rate fractions serially inside one
//! worker); `par_map` preserves input order, so the rows print
//! deterministically regardless of scheduling.

use polyserve::analysis::ServingMode;
use polyserve::config::{Features, Policy, SimConfig};
use polyserve::figures::attainment_curve;
use polyserve::util::benchkit::{f, full_scale, Bench};
use polyserve::util::threadpool::par_map;
use polyserve::workload::TraceKind;

/// Feature tweak per ablation row — plain `fn` pointers so cells are
/// `Send` for the parallel map.
type Tweak = fn(&mut Features);

fn main() {
    let mut bench = Bench::new("ablations");
    let requests = if full_scale() { 30_000 } else { 8_000 };
    let fracs = [0.7, 0.9, 1.05, 1.2, 1.35, 1.5, 1.7];
    let threads = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4);

    let variants: Vec<(&'static str, Tweak)> = vec![
        ("full PolyServe", |_f: &mut Features| {}),
        ("no load gradient (least-loaded)", |f: &mut Features| {
            f.load_gradient = false;
        }),
        ("no lazy promotion", |f: &mut Features| f.lazy_promotion = false),
        ("eager promotion", |f: &mut Features| {
            f.lazy_promotion = false;
            f.eager_promotion = true;
        }),
        ("no wait-time awareness", |f: &mut Features| {
            f.wait_time_aware = false;
        }),
        ("no dynamic chunking", |f: &mut Features| {
            f.dynamic_chunking = false;
        }),
        ("no continuous chunk prediction", |f: &mut Features| {
            f.continuous_chunk_prediction = false;
        }),
    ];

    let mut cells: Vec<(ServingMode, &'static str, Tweak)> = Vec::new();
    for mode in [ServingMode::PdDisaggregated, ServingMode::Colocated] {
        for &(name, tweak) in &variants {
            cells.push((mode, name, tweak));
        }
    }
    let results = par_map(cells, threads, move |_, (mode, name, tweak)| {
        let mut cfg = SimConfig {
            trace: TraceKind::ShareGpt,
            mode,
            policy: Policy::PolyServe,
            requests,
            ..Default::default()
        };
        tweak(&mut cfg.features);
        if cfg.validate().is_err() {
            return None;
        }
        // Inner sweep serial (threads = 1): the outer fan-out already
        // saturates the pool.
        let (curve, opt) = attainment_curve(&cfg, &fracs, 1);
        let g = curve.goodput_at(0.9).unwrap_or(0.0);
        Some(vec![
            mode.name().into(),
            name.to_string(),
            f(g, 1),
            f(100.0 * g / opt.max(1e-9), 1),
        ])
    });

    let rows: Vec<Vec<String>> = results.into_iter().flatten().collect();
    bench.table(
        "Ablations: goodput@90% per disabled mechanism (sharegpt, 20 inst)",
        &["mode", "variant", "goodput_rps", "%of_optimal"],
        &rows,
    );
    bench.finish();
}
