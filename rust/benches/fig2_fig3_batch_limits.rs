//! Fig 2 + Fig 3 reproduction: closed-form batch-size limits.

use polyserve::analysis::{fig2_decode_batch_series, fig3_coloc_batch_series};
use polyserve::model::CostModel;
use polyserve::util::benchkit::Bench;

fn main() {
    let mut bench = Bench::new("fig2_fig3");
    let cm = CostModel::h200_llama8b();
    let tpots = [16.0, 20.0, 25.0, 30.0, 40.0, 50.0, 75.0, 100.0, 150.0, 200.0];
    let configs = [(512u64, 512u64), (1000, 1000), (1000, 4000), (4000, 1000), (4000, 4000)];

    // Fig 2: decode batch vs TPOT per (p,d).
    let mut rows = Vec::new();
    for &tpot in &tpots {
        let mut row = vec![format!("{tpot:.0}")];
        for &(p, d) in &configs {
            let s = fig2_decode_batch_series(&cm, p, d, &[tpot]);
            row.push(s[0].batch.to_string());
        }
        rows.push(row);
    }
    let headers: Vec<String> = std::iter::once("TPOT_ms".to_string())
        .chain(configs.iter().map(|(p, d)| format!("B@({p},{d})")))
        .collect();
    let h: Vec<&str> = headers.iter().map(String::as_str).collect();
    bench.table("Fig 2: max decode batch (PD)", &h, &rows);

    // Paper anchors: (1000,4000) B≈50 @20ms, ≈150 @40ms.
    let b20 = cm.max_decode_batch(20.0, 3000);
    let b40 = cm.max_decode_batch(40.0, 3000);
    bench.table(
        "Fig 2 anchors vs paper",
        &["anchor", "paper", "ours"],
        &[
            vec!["(1000,4000)@20ms".into(), "~50".into(), b20.to_string()],
            vec!["(1000,4000)@40ms".into(), "~150".into(), b40.to_string()],
        ],
    );

    // Fig 3: coloc token batch vs TPOT for TTFT budgets.
    for ttft in [300.0, 700.0, 2000.0] {
        let mut rows = Vec::new();
        for &tpot in &tpots {
            let mut row = vec![format!("{tpot:.0}")];
            for &(p, d) in &configs {
                let s = fig3_coloc_batch_series(&cm, p, d, ttft, &[tpot]);
                row.push(s[0].batch.to_string());
            }
            rows.push(row);
        }
        bench.table(&format!("Fig 3: max coloc batch, TTFT={ttft:.0}ms"), &h, &rows);
    }
    bench.finish();
}
