//! Fig 6 reproduction: overall DSLO attainment (and per-TPOT-tier
//! breakdown) at request rates from 20% to 120% of the optimal bound,
//! per trace × serving mode × policy, plus the goodput@90% summary and
//! PolyServe's gain over the best baseline (the paper's headline
//! 1.23× PD / 1.18× CO).
//!
//! Default: 4 traces × 3000 requests/cell. POLYSERVE_FULL=1 runs all 8
//! traces at the paper's 20 instances with 30k requests/cell.

use polyserve::analysis::ServingMode;
use polyserve::config::{Policy, SimConfig};
use polyserve::figures::Experiment;
use polyserve::metrics::AttainmentCurve;
use polyserve::util::benchkit::{f, full_scale, Bench};
use polyserve::util::threadpool::par_map;
use polyserve::workload::TraceKind;

fn main() {
    let mut bench = Bench::new("fig6");
    let full = full_scale();
    let traces: Vec<TraceKind> = if full {
        TraceKind::ALL.to_vec()
    } else {
        vec![
            TraceKind::ShareGpt,
            TraceKind::Lmsys,
            TraceKind::Splitwise,
            TraceKind::Uniform512x512,
        ]
    };
    let requests = if full { 30_000 } else { 8_000 };
    let fracs = [0.2, 0.4, 0.6, 0.8, 0.9, 1.0, 1.1, 1.2, 1.35, 1.5, 1.7];
    let threads = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4);

    // Build the full cell grid and run it in parallel.
    struct Cell {
        trace: TraceKind,
        mode: ServingMode,
        policy: Policy,
        frac: f64,
    }
    let mut cells = Vec::new();
    for &trace in &traces {
        for mode in [ServingMode::PdDisaggregated, ServingMode::Colocated] {
            for policy in [Policy::PolyServe, Policy::Random, Policy::Minimal, Policy::Chunk] {
                if policy == Policy::Chunk && mode == ServingMode::PdDisaggregated {
                    continue;
                }
                for &frac in &fracs {
                    cells.push(Cell { trace, mode, policy, frac });
                }
            }
        }
    }
    let results = par_map(cells, threads, move |_, c| {
        let cfg = SimConfig {
            trace: c.trace,
            mode: c.mode,
            policy: c.policy,
            requests,
            rate_frac_of_optimal: c.frac,
            ..Default::default()
        };
        let exp = Experiment::prepare(&cfg);
        let res = exp.run();
        let tiers: Vec<(u64, f64)> = res
            .attainment
            .per_tier
            .iter()
            .map(|&(t, n, ok)| (t, ok as f64 / n.max(1) as f64))
            .collect();
        (
            c.trace,
            c.mode,
            c.policy,
            exp.rate_rps,
            exp.optimal_rps,
            res.attainment.overall(),
            tiers,
        )
    });

    // Attainment table (per cell, with tier breakdown).
    let headers = ["trace", "mode", "policy", "rate_rps", "attain", "t20", "t30", "t50", "t100"];
    let mut rows = Vec::new();
    for (trace, mode, policy, rate, _opt, att, tiers) in &results {
        let mut row = vec![
            trace.name().to_string(),
            mode.name().to_string(),
            policy.label(*mode),
            f(*rate, 1),
            f(*att, 3),
        ];
        for tpot in [20u64, 30, 50, 100] {
            row.push(
                tiers
                    .iter()
                    .find(|(t, _)| *t == tpot)
                    .map(|(_, a)| f(*a, 3))
                    .unwrap_or_else(|| "-".into()),
            );
        }
        rows.push(row);
    }
    bench.table("Fig 6: DSLO attainment by rate (tier breakdown)", &headers, &rows);

    // Goodput@90% summary + PolyServe gain.
    let mut rows = Vec::new();
    for &trace in &traces {
        for mode in [ServingMode::PdDisaggregated, ServingMode::Colocated] {
            let mut goodputs: Vec<(Policy, f64, f64)> = Vec::new();
            for policy in [Policy::PolyServe, Policy::Random, Policy::Minimal, Policy::Chunk] {
                let mut curve = AttainmentCurve::default();
                let mut opt = 0.0;
                for (t, m, p, rate, o, att, _) in &results {
                    if *t == trace && *m == mode && *p == policy {
                        curve.push(*rate, *att);
                        opt = *o;
                    }
                }
                if let Some(g) = curve.goodput_at(0.9) {
                    goodputs.push((policy, g, opt));
                }
            }
            let Some(ps) = goodputs.iter().find(|(p, _, _)| *p == Policy::PolyServe) else {
                continue;
            };
            let best_base = goodputs
                .iter()
                .filter(|(p, _, _)| *p != Policy::PolyServe)
                .map(|(_, g, _)| *g)
                .fold(0.0, f64::max);
            rows.push(vec![
                trace.name().to_string(),
                mode.name().to_string(),
                f(ps.1, 1),
                f(best_base, 1),
                f(ps.1 / best_base.max(1e-9), 2),
                f(100.0 * ps.1 / ps.2.max(1e-9), 1),
            ]);
        }
    }
    bench.table(
        "Fig 6 summary: goodput@90% (PolyServe vs best baseline; paper: 1.23x PD / 1.18x CO)",
        &["trace", "mode", "polyserve", "best_base", "gain_x", "%of_optimal"],
        &rows,
    );
    bench.finish();
}
