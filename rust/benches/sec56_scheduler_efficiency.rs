//! §5.6 reproduction: scheduler efficiency — how many requests per
//! second the PolyServe router can arrange as the fleet grows. This is
//! a *real* timing benchmark of the Rust scheduler hot path (the paper
//! measures its C++ scheduler at 4825 req/s per server, >100 servers in
//! real time).

use polyserve::analysis::ServingMode;
use polyserve::config::SimConfig;
use polyserve::coordinator::{PolyServeRouter, RouteCtx, Router, ShardedRouter};
use polyserve::model::CostModel;
use polyserve::profile::ProfileTable;
use polyserve::sim::{Cluster, SimRequest};
use polyserve::slo::{DsloTracker, Slo};
use polyserve::util::benchkit::Bench;
use polyserve::util::rng::Rng;
use polyserve::workload::Request;

/// Build a loaded cluster + request population for routing timing.
fn setup(n_servers: usize, seed: u64) -> (Cluster, Vec<SimRequest>) {
    let cm = CostModel::h200_llama8b();
    let mut cluster = Cluster::build(
        ServingMode::PdDisaggregated,
        n_servers,
        0.25,
        4,
        &cm,
        true,
    );
    let mut rng = Rng::new(seed);
    let tiers = [20u64, 30, 50, 100];
    let mut requests = Vec::new();
    // Populate decode servers with resident requests.
    let decode_ids: Vec<usize> = cluster
        .instances
        .iter()
        .filter(|i| i.role == polyserve::sim::Role::Decode)
        .map(|i| i.id)
        .collect();
    for (di, &id) in decode_ids.iter().enumerate() {
        let k = di % 4;
        cluster.assign[id] = polyserve::sim::TierAssign::Tier(k);
        for _ in 0..40 {
            let p = rng.range_u64(16, 2000) as u32;
            let d = rng.range_u64(16, 800) as u32;
            let idx = requests.len();
            let slo = Slo::new(500, tiers[k]);
            requests.push(SimRequest {
                req: Request { id: idx as u64, arrival_ms: 0, prefill_len: p, decode_len: d, slo },
                tier: k,
                tracker: DsloTracker::new(0, slo),
                prefill_done: p,
                decoded: rng.range_u64(1, 50) as u32,
                first_token_ms: Some(1),
                finish_ms: None,
                decode_instance: Some(id),
            });
            cluster.instances[id].running.push(polyserve::sim::instance::RunningReq {
                req_idx: idx,
                paused: false,
            });
        }
    }
    // Fresh decode-phase requests to route.
    for i in 0..4096 {
        let k = (i % 4) as usize;
        let p = rng.range_u64(16, 2000) as u32;
        let slo = Slo::new(500, tiers[k]);
        let idx = requests.len();
        requests.push(SimRequest {
            req: Request { id: idx as u64, arrival_ms: 0, prefill_len: p, decode_len: 300, slo },
            tier: k,
            tracker: DsloTracker::new(0, slo),
            prefill_done: p,
            decoded: 1,
            first_token_ms: Some(1),
            finish_ms: None,
            decode_instance: None,
        });
    }
    (cluster, requests)
}

fn main() {
    let mut bench = Bench::new("sec56");
    let profile = ProfileTable::from_cost_model(&CostModel::h200_llama8b());
    for &n_servers in &[10usize, 20, 50, 100, 200] {
        let cfg = SimConfig::default();
        let (mut cluster, mut requests) = setup(n_servers, 42);
        let mut router = PolyServeRouter::new(&cfg, 300.0);
        let fresh_start = requests.len() - 4096;
        let mut i = 0usize;
        bench.time(
            &format!("route_decode x1 @ {n_servers} servers"),
            Some(1.0),
            || {
                let mut ctx = RouteCtx {
                    now: 1_000,
                    cluster: &mut cluster,
                    requests: &mut requests,
                    profile: &profile,
                    mode: ServingMode::PdDisaggregated,
                    kv_transfer_ms: 2,
                };
                let idx = fresh_start + (i % 4096);
                i += 1;
                let target = router.route_decode(1_000, idx, &mut ctx);
                // Undo state mutation so the cluster stays steady.
                if let Some(t) = target {
                    ctx.cluster.instances[t].decode_queue.clear();
                }
                std::hint::black_box(target);
            },
        );
    }
    // §5.6 scale-out: "PolyServe can further scale by introducing more
    // schedulers that manage independent servers" — sharded routing at
    // 200 servers.
    for &shards in &[1usize, 2, 4, 8] {
        let cfg = SimConfig::default();
        let (mut cluster, mut requests) = setup(200, 42);
        let mut router = ShardedRouter::new(&cfg, 300.0, shards);
        let fresh_start = requests.len() - 4096;
        let mut i = 0usize;
        bench.time(
            &format!("sharded route_decode @200 servers, {shards} shards"),
            Some(1.0),
            || {
                let mut ctx = RouteCtx {
                    now: 1_000,
                    cluster: &mut cluster,
                    requests: &mut requests,
                    profile: &profile,
                    mode: ServingMode::PdDisaggregated,
                    kv_transfer_ms: 2,
                };
                let idx = fresh_start + (i % 4096);
                i += 1;
                let target = router.route_decode(1_000, idx, &mut ctx);
                if let Some(t) = target {
                    ctx.cluster.instances[t].decode_queue.clear();
                }
                std::hint::black_box(target);
            },
        );
    }

    println!("\n(paper: 4825 req/s per server-equivalent; >100 servers in real time)");
    bench.finish();
}
