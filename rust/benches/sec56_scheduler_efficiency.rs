//! §5.6 reproduction: scheduler efficiency — how many requests per
//! second the PolyServe router can arrange as the fleet grows. This is
//! a *real* timing benchmark of the Rust scheduler hot path (the paper
//! measures its C++ scheduler at 4825 req/s per server, >100 servers in
//! real time).
//!
//! The (fleet size / shard count) fixtures are built in parallel via
//! `par_map`; the timing loops themselves stay strictly serial so pool
//! contention never skews the measured routing latency.

use polyserve::analysis::ServingMode;
use polyserve::config::SimConfig;
use polyserve::coordinator::{PolyServeRouter, RouteCtx, Router, ShardedRouter};
use polyserve::model::CostModel;
use polyserve::profile::ProfileTable;
use polyserve::sim::{Cluster, SimRequest};
use polyserve::slo::Slo;
use polyserve::util::benchkit::Bench;
use polyserve::util::rng::Rng;
use polyserve::util::threadpool::par_map;
use polyserve::workload::Request;

/// Leak a fixture request so the arena's borrowed immutable half has a
/// `'static` home (benches build a bounded fixture set once).
fn leaked(id: u64, p: u32, d: u32, slo: Slo) -> &'static Request {
    Box::leak(Box::new(Request {
        id,
        arrival_ms: 0,
        prefill_len: p,
        decode_len: d,
        slo,
        model: 0,
    }))
}

/// Build a loaded cluster + request population for routing timing.
fn setup(n_servers: usize, seed: u64) -> (Cluster, Vec<SimRequest<'static>>) {
    let cm = CostModel::h200_llama8b();
    let mut cluster = Cluster::build(
        ServingMode::PdDisaggregated,
        n_servers,
        0.25,
        4,
        &cm,
        true,
    );
    let mut rng = Rng::new(seed);
    let tiers = [20u64, 30, 50, 100];
    let mut requests = Vec::new();
    // Populate decode servers with resident requests.
    let decode_ids: Vec<usize> = cluster
        .instances
        .iter()
        .filter(|i| i.role == polyserve::sim::Role::Decode)
        .map(|i| i.id)
        .collect();
    for (di, &id) in decode_ids.iter().enumerate() {
        let k = di % 4;
        cluster.set_assign(id, polyserve::sim::TierAssign::Tier(k));
        for _ in 0..40 {
            let p = rng.range_u64(16, 2000) as u32;
            let d = rng.range_u64(16, 800) as u32;
            let idx = requests.len();
            let mut r =
                SimRequest::new(leaked(idx as u64, p, d, Slo::new(500, tiers[k])), k);
            r.prefill_done = p;
            r.decoded = rng.range_u64(1, 50) as u32;
            r.first_token_ms = Some(1);
            r.decode_instance = Some(id);
            requests.push(r);
            // Cache-coherent residency: keeps the O(1) load counters in
            // sync (pushing `running` directly would desync them).
            cluster.instances[id].push_running(idx, &requests);
        }
        // Re-key discipline: the load-ordered tier indices must see the
        // fixture's hand-built residency, exactly as the simulator
        // re-keys after every mutation.
        cluster.refresh_load(id);
    }
    // Fresh decode-phase requests to route.
    for i in 0..4096 {
        let k = (i % 4) as usize;
        let p = rng.range_u64(16, 2000) as u32;
        let idx = requests.len();
        let mut r =
            SimRequest::new(leaked(idx as u64, p, 300, Slo::new(500, tiers[k])), k);
        r.prefill_done = p;
        r.decoded = 1;
        r.first_token_ms = Some(1);
        requests.push(r);
    }
    (cluster, requests)
}

fn main() {
    let mut bench = Bench::new("sec56");
    let profile = ProfileTable::from_cost_model(&CostModel::h200_llama8b());
    let threads = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4);

    // Fixtures in parallel, timing serial.
    let sizes = vec![10usize, 20, 50, 100, 200];
    let setups = par_map(sizes, threads, |_, n| (n, setup(n, 42)));
    for (n_servers, (mut cluster, mut requests)) in setups {
        let cfg = SimConfig::default();
        let mut router = PolyServeRouter::new(&cfg, 300.0);
        let fresh_start = requests.len() - 4096;
        let mut i = 0usize;
        bench.time(
            &format!("route_decode x1 @ {n_servers} servers"),
            Some(1.0),
            || {
                let mut ctx = RouteCtx {
                    now: 1_000,
                    cluster: &mut cluster,
                    requests: &mut requests,
                    profile: &profile,
                    mode: ServingMode::PdDisaggregated,
                    kv_transfer_ms: 2,
                };
                let idx = fresh_start + (i % 4096);
                i += 1;
                let target = router.route_decode(1_000, idx, &mut ctx);
                // Undo state mutation so the cluster stays steady
                // (cache-coherently: the handoff KV counter resets and
                // the ordered index is re-keyed, as the real loop would).
                if let Some(t) = target {
                    ctx.cluster.instances[t].clear_decode_queue();
                    ctx.cluster.refresh_load(t);
                }
                std::hint::black_box(target);
            },
        );
    }
    // §5.6 scale-out: "PolyServe can further scale by introducing more
    // schedulers that manage independent servers" — sharded routing at
    // 200 servers. Fixtures again built in parallel.
    let shard_counts = vec![1usize, 2, 4, 8];
    let sharded_setups = par_map(shard_counts, threads, |_, shards| (shards, setup(200, 42)));
    for (shards, (mut cluster, mut requests)) in sharded_setups {
        let cfg = SimConfig::default();
        let mut router = ShardedRouter::new(&cfg, 300.0, shards);
        let fresh_start = requests.len() - 4096;
        let mut i = 0usize;
        bench.time(
            &format!("sharded route_decode @200 servers, {shards} shards"),
            Some(1.0),
            || {
                let mut ctx = RouteCtx {
                    now: 1_000,
                    cluster: &mut cluster,
                    requests: &mut requests,
                    profile: &profile,
                    mode: ServingMode::PdDisaggregated,
                    kv_transfer_ms: 2,
                };
                let idx = fresh_start + (i % 4096);
                i += 1;
                let target = router.route_decode(1_000, idx, &mut ctx);
                if let Some(t) = target {
                    ctx.cluster.instances[t].clear_decode_queue();
                    ctx.cluster.refresh_load(t);
                }
                std::hint::black_box(target);
            },
        );
    }

    println!("\n(paper: 4825 req/s per server-equivalent; >100 servers in real time)");
    bench.finish();
}
