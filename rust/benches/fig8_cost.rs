//! Fig 8 reproduction: per-request serving cost (instance·seconds) at
//! increasing request rates, all policies meeting ~90% attainment.
//!
//! PolyServe gets an ample pool (auto-scaling decides usage; cost =
//! allocated instance·s / request). The CO-Chunk baseline is sized by
//! searching the smallest instance count that reaches 90% attainment
//! (cost = fleet instance·s / request), per §5.4.
//!
//! Beyond the paper: an *elastic* PolyServe row (load-gradient fleet
//! scaler, min 6 / max 48) reports the cloud-bill view —
//! active-instance·s per request — which the fixed 48-instance pool
//! cannot improve no matter how little of it the router allocates.

use polyserve::analysis::ServingMode;
use polyserve::config::{Policy, ScalerKind, SimConfig};
use polyserve::figures::{auto_prefill_frac, size_elastic_pd_cell, Experiment};
use polyserve::util::benchkit::{f, full_scale, Bench};
use polyserve::util::threadpool::par_map;
use polyserve::workload::TraceKind;

/// (attainment, alloc cost/req, active-bill cost/req)
fn run_cell(cfg: &SimConfig) -> (f64, f64, f64) {
    let exp = Experiment::prepare(cfg);
    let res = exp.run();
    (
        res.attainment.overall(),
        res.cost.cost_per_request_s(),
        res.cost.active_cost_per_request_s(),
    )
}

fn main() {
    let mut bench = Bench::new("fig8");
    let requests = if full_scale() { 30_000 } else { 4_000 };
    let trace = TraceKind::ShareGpt;
    let rates = [50.0, 100.0, 150.0, 200.0, 250.0];
    let threads = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4);

    // PolyServe with an ample (fixed) pool.
    let ps_cells: Vec<SimConfig> = rates
        .iter()
        .flat_map(|&r| {
            [ServingMode::PdDisaggregated, ServingMode::Colocated].map(|mode| SimConfig {
                trace,
                mode,
                policy: Policy::PolyServe,
                instances: 48,
                requests,
                rate_rps: Some(r),
                ..Default::default()
            })
        })
        .collect();
    let ps_results = par_map(ps_cells.clone(), threads, |_, cfg| run_cell(&cfg));

    // Elastic PolyServe: same rates, the fleet itself follows demand.
    // The PD prefill cluster does not scale, so size it for the *peak*
    // fleet (matching the 48-instance comparator row) rather than the
    // small initial fleet — otherwise elastic PD rows bottleneck on an
    // undersized prefill cluster for reasons unrelated to the scaler.
    let pd_peak_frac = auto_prefill_frac(&SimConfig {
        trace,
        mode: ServingMode::PdDisaggregated,
        policy: Policy::PolyServe,
        instances: 48,
        requests,
        rate_rps: Some(rates[0]),
        ..Default::default()
    });
    let el_cells: Vec<SimConfig> = rates
        .iter()
        .flat_map(|&r| {
            [ServingMode::PdDisaggregated, ServingMode::Colocated].map(|mode| {
                let mut cfg = SimConfig {
                    trace,
                    mode,
                    policy: Policy::PolyServe,
                    instances: 12,
                    requests,
                    rate_rps: Some(r),
                    ..Default::default()
                };
                cfg.elastic.scaler = ScalerKind::Gradient;
                cfg.elastic.min_instances = 6;
                cfg.elastic.max_instances = 48;
                cfg.elastic.provision_delay_ms = 15_000;
                cfg.elastic.scale_eval_ms = 1_000;
                if mode == ServingMode::PdDisaggregated {
                    size_elastic_pd_cell(&mut cfg, 48, pd_peak_frac, |_| 6);
                }
                cfg
            })
        })
        .collect();
    let el_results = par_map(el_cells.clone(), threads, |_, cfg| run_cell(&cfg));

    // CO-Chunk sized to 90%: try increasing instance counts.
    let sizes = [4usize, 8, 12, 16, 20, 24, 32, 40, 48];
    let chunk_cells: Vec<(f64, usize)> = rates
        .iter()
        .flat_map(|&r| sizes.iter().map(move |&s| (r, s)))
        .collect();
    let chunk_results = par_map(chunk_cells.clone(), threads, move |_, (r, s)| {
        let cfg = SimConfig {
            trace,
            mode: ServingMode::Colocated,
            policy: Policy::Chunk,
            instances: s,
            requests,
            rate_rps: Some(r),
            ..Default::default()
        };
        run_cell(&cfg)
    });

    let mut rows = Vec::new();
    for (i, cfg) in ps_cells.iter().enumerate() {
        let (att, cost, active) = ps_results[i];
        rows.push(vec![
            format!("{:.0}", cfg.rate_rps.unwrap()),
            cfg.policy.label(cfg.mode),
            "48(auto)".into(),
            f(att, 3),
            f(cost, 3),
            f(active, 3),
        ]);
    }
    for (i, cfg) in el_cells.iter().enumerate() {
        let (att, cost, active) = el_results[i];
        rows.push(vec![
            format!("{:.0}", cfg.rate_rps.unwrap()),
            format!("{}+elastic", cfg.policy.label(cfg.mode)),
            format!("{}..{}", cfg.elastic.min_instances, cfg.elastic.max_instances),
            f(att, 3),
            f(cost, 3),
            f(active, 3),
        ]);
    }
    for (ri, &rate) in rates.iter().enumerate() {
        // smallest size reaching 90%
        let mut chosen: Option<(usize, f64, f64, f64)> = None;
        for (si, &size) in sizes.iter().enumerate() {
            let (att, cost, active) = chunk_results[ri * sizes.len() + si];
            if att >= 0.9 {
                chosen = Some((size, att, cost, active));
                break;
            }
        }
        match chosen {
            Some((size, att, cost, active)) => rows.push(vec![
                format!("{rate:.0}"),
                "CO-Chunk".into(),
                size.to_string(),
                f(att, 3),
                f(cost, 3),
                f(active, 3),
            ]),
            None => rows.push(vec![
                format!("{rate:.0}"),
                "CO-Chunk".into(),
                ">48".into(),
                "-".into(),
                "-".into(),
                "-".into(),
            ]),
        }
    }
    bench.table(
        "Fig 8: cost per request at >=90% attainment",
        &[
            "rate_rps",
            "policy",
            "instances",
            "attain",
            "cost_inst_s_per_req",
            "active_bill_inst_s_per_req",
        ],
        &rows,
    );
    bench.finish();
}
