//! Fig 8 reproduction: per-request serving cost (instance·seconds) at
//! increasing request rates, all policies meeting ~90% attainment.
//!
//! PolyServe gets an ample pool (auto-scaling decides usage; cost =
//! allocated instance·s / request). The CO-Chunk baseline is sized by
//! searching the smallest instance count that reaches 90% attainment
//! (cost = fleet instance·s / request), per §5.4.

use polyserve::analysis::ServingMode;
use polyserve::config::{Policy, SimConfig};
use polyserve::figures::Experiment;
use polyserve::util::benchkit::{f, full_scale, Bench};
use polyserve::util::threadpool::par_map;
use polyserve::workload::TraceKind;

fn run_cell(cfg: &SimConfig) -> (f64, f64) {
    let exp = Experiment::prepare(cfg);
    let res = exp.run();
    (res.attainment.overall(), res.cost.cost_per_request_s())
}

fn main() {
    let mut bench = Bench::new("fig8");
    let requests = if full_scale() { 30_000 } else { 4_000 };
    let trace = TraceKind::ShareGpt;
    let rates = [50.0, 100.0, 150.0, 200.0, 250.0];
    let threads = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4);

    // PolyServe with an ample pool.
    let ps_cells: Vec<SimConfig> = rates
        .iter()
        .flat_map(|&r| {
            [ServingMode::PdDisaggregated, ServingMode::Colocated].map(|mode| SimConfig {
                trace,
                mode,
                policy: Policy::PolyServe,
                instances: 48,
                requests,
                rate_rps: Some(r),
                ..Default::default()
            })
        })
        .collect();
    let ps_results = par_map(ps_cells.clone(), threads, |_, cfg| run_cell(&cfg));

    // CO-Chunk sized to 90%: try increasing instance counts.
    let sizes = [4usize, 8, 12, 16, 20, 24, 32, 40, 48];
    let chunk_cells: Vec<(f64, usize)> = rates
        .iter()
        .flat_map(|&r| sizes.iter().map(move |&s| (r, s)))
        .collect();
    let chunk_results = par_map(chunk_cells.clone(), threads, move |_, (r, s)| {
        let cfg = SimConfig {
            trace,
            mode: ServingMode::Colocated,
            policy: Policy::Chunk,
            instances: s,
            requests,
            rate_rps: Some(r),
            ..Default::default()
        };
        run_cell(&cfg)
    });

    let mut rows = Vec::new();
    for (i, cfg) in ps_cells.iter().enumerate() {
        let (att, cost) = ps_results[i];
        rows.push(vec![
            format!("{:.0}", cfg.rate_rps.unwrap()),
            cfg.policy.label(cfg.mode),
            "48(auto)".into(),
            f(att, 3),
            f(cost, 3),
        ]);
    }
    for (ri, &rate) in rates.iter().enumerate() {
        // smallest size reaching 90%
        let mut chosen: Option<(usize, f64, f64)> = None;
        for (si, &size) in sizes.iter().enumerate() {
            let (att, cost) = chunk_results[ri * sizes.len() + si];
            if att >= 0.9 {
                chosen = Some((size, att, cost));
                break;
            }
        }
        match chosen {
            Some((size, att, cost)) => rows.push(vec![
                format!("{rate:.0}"),
                "CO-Chunk".into(),
                size.to_string(),
                f(att, 3),
                f(cost, 3),
            ]),
            None => rows.push(vec![
                format!("{rate:.0}"),
                "CO-Chunk".into(),
                ">48".into(),
                "-".into(),
                "-".into(),
            ]),
        }
    }
    bench.table(
        "Fig 8: cost per request at >=90% attainment",
        &["rate_rps", "policy", "instances", "attain", "cost_inst_s_per_req"],
        &rows,
    );
    bench.finish();
}
