//! Minimal offline substitute for the `anyhow` crate.
//!
//! Provides [`Error`] (a context-chain error), [`Result`], the
//! [`anyhow!`] / [`bail!`] / [`ensure!`] macros, and the [`Context`]
//! extension trait for `Result` and `Option` — the subset this
//! repository uses. Like the real crate, `Error` deliberately does not
//! implement `std::error::Error`, which is what allows the blanket
//! `From<E: std::error::Error>` conversion behind `?`.

use std::fmt;

/// A dynamic error with a chain of context messages.
///
/// `chain[0]` is the outermost (most recently attached) context; the
/// last element is the root cause. `{}` prints the outermost message,
/// `{:#}` prints the whole chain separated by `": "`, and `{:?}` prints
/// the outermost message followed by a `Caused by:` list — matching the
/// real crate's formatting closely enough for logs and tests.
pub struct Error {
    chain: Vec<String>,
}

impl Error {
    /// Construct from a displayable message.
    pub fn msg<M: fmt::Display>(message: M) -> Error {
        Error {
            chain: vec![message.to_string()],
        }
    }

    /// Attach an outer context message.
    pub fn context<C: fmt::Display>(mut self, context: C) -> Error {
        self.chain.insert(0, context.to_string());
        self
    }

    /// The context chain, outermost first.
    pub fn chain(&self) -> impl Iterator<Item = &str> {
        self.chain.iter().map(String::as_str)
    }

    /// The innermost (root-cause) message.
    pub fn root_cause(&self) -> &str {
        self.chain.last().map(String::as_str).unwrap_or("")
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if f.alternate() {
            for (i, msg) in self.chain.iter().enumerate() {
                if i > 0 {
                    f.write_str(": ")?;
                }
                f.write_str(msg)?;
            }
            Ok(())
        } else {
            f.write_str(self.chain.first().map(String::as_str).unwrap_or(""))
        }
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(self.chain.first().map(String::as_str).unwrap_or(""))?;
        if self.chain.len() > 1 {
            f.write_str("\n\nCaused by:")?;
            for msg in &self.chain[1..] {
                write!(f, "\n    {msg}")?;
            }
        }
        Ok(())
    }
}

impl<E> From<E> for Error
where
    E: std::error::Error + Send + Sync + 'static,
{
    fn from(err: E) -> Error {
        let mut chain = vec![err.to_string()];
        let mut source = err.source();
        while let Some(s) = source {
            chain.push(s.to_string());
            source = s.source();
        }
        Error { chain }
    }
}

/// `anyhow::Result<T>`: a `Result` defaulting to [`Error`].
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Extension trait adding `.context()` / `.with_context()` to `Result`
/// and `Option`.
pub trait Context<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T, Error>;
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T, Error>;
}

impl<T, E: Into<Error>> Context<T> for std::result::Result<T, E> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T, Error> {
        self.map_err(|e| e.into().context(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T, Error> {
        self.map_err(|e| e.into().context(f()))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T, Error> {
        self.ok_or_else(|| Error::msg(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T, Error> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Construct an [`Error`] from a format string or displayable value.
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(format!($msg))
    };
    ($err:expr $(,)?) => {
        $crate::Error::msg($err)
    };
    ($fmt:expr, $($arg:tt)*) => {
        $crate::Error::msg(format!($fmt, $($arg)*))
    };
}

/// Return early with an error.
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::anyhow!($($arg)*))
    };
}

/// Return early with an error unless the condition holds.
#[macro_export]
macro_rules! ensure {
    ($cond:expr $(,)?) => {
        if !($cond) {
            return Err($crate::anyhow!(concat!("condition failed: ", stringify!($cond))));
        }
    };
    ($cond:expr, $($arg:tt)*) => {
        if !($cond) {
            return Err($crate::anyhow!($($arg)*));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> std::io::Error {
        std::io::Error::new(std::io::ErrorKind::NotFound, "missing file")
    }

    #[test]
    fn question_mark_converts_std_errors() {
        fn inner() -> Result<()> {
            Err(io_err())?;
            Ok(())
        }
        let e = inner().unwrap_err();
        assert_eq!(e.to_string(), "missing file");
    }

    #[test]
    fn context_chains_outermost_first() {
        let r: std::result::Result<(), std::io::Error> = Err(io_err());
        let e = r.context("reading manifest").unwrap_err();
        assert_eq!(e.to_string(), "reading manifest");
        assert_eq!(format!("{e:#}"), "reading manifest: missing file");
        assert!(format!("{e:?}").contains("Caused by:"));
    }

    #[test]
    fn option_context() {
        let v: Option<u32> = None;
        let e = v.with_context(|| format!("no value {}", 7)).unwrap_err();
        assert_eq!(e.to_string(), "no value 7");
        assert_eq!(Some(3u32).context("x").unwrap(), 3);
    }

    #[test]
    fn macros_build_errors() {
        fn f(x: u32) -> Result<u32> {
            ensure!(x < 10, "x too big: {x}");
            if x == 5 {
                bail!("five is right out");
            }
            Ok(x)
        }
        assert_eq!(f(3).unwrap(), 3);
        assert_eq!(f(5).unwrap_err().to_string(), "five is right out");
        assert_eq!(f(11).unwrap_err().to_string(), "x too big: 11");
        let e = anyhow!("code {}", 404);
        assert_eq!(e.to_string(), "code 404");
    }
}
