//! Offline stub of the XLA/PJRT bindings used by `runtime::engine`.
//!
//! The container this repo builds in has no PJRT plugin, so the real
//! bindings cannot link. This stub keeps the whole crate compiling:
//! every constructor that would touch PJRT returns [`Error`], and the
//! runtime layer's own artifact gating (`make artifacts` absent →
//! tests skip, `serve` reports the error) handles the rest. Swap this
//! path dependency for the real bindings to run on actual hardware.

use std::fmt;

/// Error returned by every stubbed PJRT entry point.
#[derive(Debug, Clone)]
pub struct Error(String);

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.0)
    }
}

impl std::error::Error for Error {}

pub type Result<T> = std::result::Result<T, Error>;

fn unavailable(what: &str) -> Error {
    Error(format!(
        "{what}: PJRT runtime unavailable (offline xla stub; link the real xla bindings to serve)"
    ))
}

/// A host-side literal (stub: shape/values are not retained).
#[derive(Debug, Clone, Default)]
pub struct Literal;

impl Literal {
    /// 1-D literal from a slice.
    pub fn vec1<T>(_values: &[T]) -> Literal {
        Literal
    }

    /// 0-D literal from a scalar.
    pub fn scalar<T>(_value: T) -> Literal {
        Literal
    }

    pub fn reshape(&self, _dims: &[i64]) -> Result<Literal> {
        Ok(Literal)
    }

    pub fn to_vec<T>(&self) -> Result<Vec<T>> {
        Err(unavailable("Literal::to_vec"))
    }

    pub fn to_tuple3(&self) -> Result<(Literal, Literal, Literal)> {
        Err(unavailable("Literal::to_tuple3"))
    }
}

/// Parsed HLO module (stub).
#[derive(Debug, Clone)]
pub struct HloModuleProto;

impl HloModuleProto {
    pub fn from_text_file(_path: &str) -> Result<HloModuleProto> {
        Err(unavailable("HloModuleProto::from_text_file"))
    }
}

/// An XLA computation (stub).
#[derive(Debug, Clone)]
pub struct XlaComputation;

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation
    }
}

/// A device buffer returned by execution (stub).
#[derive(Debug, Clone)]
pub struct PjRtBuffer;

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Err(unavailable("PjRtBuffer::to_literal_sync"))
    }
}

/// A compiled executable (stub).
#[derive(Debug, Clone)]
pub struct PjRtLoadedExecutable;

impl PjRtLoadedExecutable {
    pub fn execute<T>(&self, _args: &[T]) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(unavailable("PjRtLoadedExecutable::execute"))
    }
}

/// A PJRT client (stub).
#[derive(Debug, Clone)]
pub struct PjRtClient;

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient> {
        Err(unavailable("PjRtClient::cpu"))
    }

    pub fn platform_name(&self) -> String {
        "stub".to_string()
    }

    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        Err(unavailable("PjRtClient::compile"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stubs_error_cleanly() {
        assert!(PjRtClient::cpu().is_err());
        assert!(HloModuleProto::from_text_file("x").is_err());
        let e = Literal::vec1(&[1i32, 2]).to_vec::<i32>().unwrap_err();
        assert!(e.to_string().contains("unavailable"));
    }
}
