//! Minimal offline substitute for the `log` crate facade.
//!
//! Implements exactly the API surface this repository uses: the five
//! level macros, `log_enabled!`, the [`Log`] trait, [`Record`] /
//! [`Metadata`], [`set_boxed_logger`] / [`set_max_level`] /
//! [`max_level`]. Semantics match the real crate for that subset; the
//! rest of the facade is intentionally absent.

use std::cmp::Ordering as CmpOrdering;
use std::fmt;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::OnceLock;

/// Verbosity level of a log record.
#[repr(usize)]
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub enum Level {
    Error = 1,
    Warn = 2,
    Info = 3,
    Debug = 4,
    Trace = 5,
}

/// Maximum-verbosity filter installed via [`set_max_level`].
#[repr(usize)]
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub enum LevelFilter {
    Off = 0,
    Error = 1,
    Warn = 2,
    Info = 3,
    Debug = 4,
    Trace = 5,
}

impl PartialEq<LevelFilter> for Level {
    fn eq(&self, other: &LevelFilter) -> bool {
        *self as usize == *other as usize
    }
}

impl PartialOrd<LevelFilter> for Level {
    fn partial_cmp(&self, other: &LevelFilter) -> Option<CmpOrdering> {
        (*self as usize).partial_cmp(&(*other as usize))
    }
}

impl PartialEq<Level> for LevelFilter {
    fn eq(&self, other: &Level) -> bool {
        *self as usize == *other as usize
    }
}

impl PartialOrd<Level> for LevelFilter {
    fn partial_cmp(&self, other: &Level) -> Option<CmpOrdering> {
        (*self as usize).partial_cmp(&(*other as usize))
    }
}

impl fmt::Display for Level {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        let s = match self {
            Level::Error => "ERROR",
            Level::Warn => "WARN",
            Level::Info => "INFO",
            Level::Debug => "DEBUG",
            Level::Trace => "TRACE",
        };
        f.write_str(s)
    }
}

/// Metadata about a log record (level + target).
#[derive(Debug, Clone)]
pub struct Metadata<'a> {
    level: Level,
    target: &'a str,
}

impl<'a> Metadata<'a> {
    pub fn level(&self) -> Level {
        self.level
    }

    pub fn target(&self) -> &'a str {
        self.target
    }
}

/// One log record as handed to the installed [`Log`] backend.
#[derive(Debug, Clone)]
pub struct Record<'a> {
    metadata: Metadata<'a>,
    args: fmt::Arguments<'a>,
}

impl<'a> Record<'a> {
    pub fn metadata(&self) -> &Metadata<'a> {
        &self.metadata
    }

    pub fn level(&self) -> Level {
        self.metadata.level
    }

    pub fn target(&self) -> &'a str {
        self.metadata.target
    }

    pub fn args(&self) -> &fmt::Arguments<'a> {
        &self.args
    }
}

/// A logging backend.
pub trait Log: Send + Sync {
    fn enabled(&self, metadata: &Metadata) -> bool;
    fn log(&self, record: &Record);
    fn flush(&self);
}

/// Returned when a logger is already installed.
#[derive(Debug)]
pub struct SetLoggerError(());

impl fmt::Display for SetLoggerError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str("a logger is already installed")
    }
}

impl std::error::Error for SetLoggerError {}

static MAX_LEVEL: AtomicUsize = AtomicUsize::new(LevelFilter::Off as usize);
static LOGGER: OnceLock<Box<dyn Log>> = OnceLock::new();

/// Install a boxed logger; fails if one is already installed.
pub fn set_boxed_logger(logger: Box<dyn Log>) -> Result<(), SetLoggerError> {
    LOGGER.set(logger).map_err(|_| SetLoggerError(()))
}

/// Set the global maximum verbosity.
pub fn set_max_level(filter: LevelFilter) {
    MAX_LEVEL.store(filter as usize, Ordering::Relaxed);
}

/// Current global maximum verbosity.
pub fn max_level() -> LevelFilter {
    match MAX_LEVEL.load(Ordering::Relaxed) {
        1 => LevelFilter::Error,
        2 => LevelFilter::Warn,
        3 => LevelFilter::Info,
        4 => LevelFilter::Debug,
        5 => LevelFilter::Trace,
        _ => LevelFilter::Off,
    }
}

#[doc(hidden)]
pub fn __private_log(level: Level, target: &str, args: fmt::Arguments) {
    if (level as usize) <= MAX_LEVEL.load(Ordering::Relaxed) {
        if let Some(logger) = LOGGER.get() {
            let record = Record {
                metadata: Metadata { level, target },
                args,
            };
            if logger.enabled(&record.metadata) {
                logger.log(&record);
            }
        }
    }
}

#[doc(hidden)]
pub fn __private_enabled(level: Level) -> bool {
    (level as usize) <= MAX_LEVEL.load(Ordering::Relaxed) && LOGGER.get().is_some()
}

#[macro_export]
macro_rules! log {
    ($lvl:expr, $($arg:tt)+) => {
        $crate::__private_log($lvl, module_path!(), format_args!($($arg)+))
    };
}

#[macro_export]
macro_rules! error {
    ($($arg:tt)+) => { $crate::log!($crate::Level::Error, $($arg)+) };
}

#[macro_export]
macro_rules! warn {
    ($($arg:tt)+) => { $crate::log!($crate::Level::Warn, $($arg)+) };
}

#[macro_export]
macro_rules! info {
    ($($arg:tt)+) => { $crate::log!($crate::Level::Info, $($arg)+) };
}

#[macro_export]
macro_rules! debug {
    ($($arg:tt)+) => { $crate::log!($crate::Level::Debug, $($arg)+) };
}

#[macro_export]
macro_rules! trace {
    ($($arg:tt)+) => { $crate::log!($crate::Level::Trace, $($arg)+) };
}

/// Is logging at `level` currently enabled?
#[macro_export]
macro_rules! log_enabled {
    ($lvl:expr) => {
        $crate::__private_enabled($lvl)
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn level_filter_ordering() {
        assert!(Level::Error <= LevelFilter::Info);
        assert!(Level::Trace > LevelFilter::Info);
        assert!(LevelFilter::Off < Level::Error);
    }

    #[test]
    fn max_level_roundtrip() {
        set_max_level(LevelFilter::Debug);
        assert_eq!(max_level(), LevelFilter::Debug);
        set_max_level(LevelFilter::Off);
        assert_eq!(max_level(), LevelFilter::Off);
    }
}
