"""Golden trajectories for the Rust runtime's numeric round-trip test.

Runs the L2 model (with Pallas kernels, same weights as weights.bin) on
fixed prompts and records the greedy token trajectories. The Rust
integration test `integration_runtime.rs` replays them through the AOT
HLO executables via PJRT and must match token-for-token.
"""

from __future__ import annotations

import argparse
import json
import os

import jax.numpy as jnp
import numpy as np

from . import model as M


def trajectory(cfg, weights, prompt: list[int], steps: int) -> dict:
    kc = jnp.zeros(M.kv_cache_shape_prefill(cfg), jnp.float32)
    vc = jnp.zeros_like(kc)
    t0, kc, vc = M.prefill_chunk(
        cfg, weights,
        jnp.asarray(prompt, jnp.int32),
        jnp.int32(0), jnp.int32(len(prompt)), kc, vc,
    )
    # batch-1 decode
    kcd = jnp.zeros(M.kv_cache_shape_decode(cfg, 1), jnp.float32).at[:, 0].set(kc)
    vcd = jnp.zeros(M.kv_cache_shape_decode(cfg, 1), jnp.float32).at[:, 0].set(vc)
    toks = [int(t0)]
    t = jnp.asarray([int(t0)], jnp.int32)
    lens = jnp.asarray([len(prompt)], jnp.int32)
    for _ in range(steps):
        t, kcd, vcd = M.decode_step(cfg, weights, t, lens, kcd, vcd)
        lens = lens + 1
        toks.append(int(t[0]))
    return {"prompt": prompt, "tokens": toks}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()
    cfg = M.SMALL_CONFIG
    weights = [jnp.asarray(w) for w in M.init_weights(cfg, seed=args.seed)]
    rng = np.random.default_rng(1234)
    cases = []
    for p_len in (9, 70, 150):
        prompt = [int(x) for x in rng.integers(0, cfg.vocab, size=(p_len,))]
        cases.append(trajectory(cfg, weights, prompt, steps=8))
    out = os.path.join(args.out_dir, "golden.json")
    with open(out, "w") as f:
        json.dump({"model": cfg.name, "cases": cases}, f)
    print(f"[golden] wrote {len(cases)} trajectories to {out}")


if __name__ == "__main__":
    main()
