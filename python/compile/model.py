"""Layer-2: LLaMA-style transformer (GQA + SwiGLU + RoPE + RMSNorm).

Dimensionally faithful to the LLaMA/Qwen family the paper serves, sized
to decode in ~ms on CPU PJRT (`SMALL_CONFIG`, must match
`rust/src/model/spec.rs::small_serving`). Two jit-able entry points are
AOT-lowered per shape bucket by `aot.py`:

* ``decode_step``  — one token for each of B batched requests, reading
  and functionally updating the KV cache.
* ``prefill_chunk`` — one chunk of one request's prompt (chunked
  prefill), writing its KV into the cache; emits the first output token
  when the chunk completes the prompt.

Both call the Layer-1 Pallas kernels (interpret mode) so the kernels lower
into the same HLO the Rust runtime executes. Greedy (argmax) sampling is
baked in: the serving path is latency-deterministic, which is what the
paper's scheduler assumes.
"""

from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp
import numpy as np

from .kernels import ref
from .kernels.decode_attention import gqa_decode_attention_pallas
from .kernels.fused_ffn import swiglu_ffn_pallas
from .kernels.prefill_attention import causal_prefill_attention_pallas


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    num_layers: int
    hidden: int
    num_q_heads: int
    num_kv_heads: int
    head_dim: int
    ffn_hidden: int
    vocab: int
    max_seq_len: int
    rope_theta: float = 10000.0

    @property
    def q_dim(self) -> int:
        return self.num_q_heads * self.head_dim

    @property
    def kv_dim(self) -> int:
        return self.num_kv_heads * self.head_dim


# Must match rust/src/model/spec.rs::small_serving().
SMALL_CONFIG = ModelConfig(
    name="polyserve-small",
    num_layers=4,
    hidden=256,
    num_q_heads=4,
    num_kv_heads=2,
    head_dim=64,
    ffn_hidden=688,
    vocab=512,
    max_seq_len=512,
)

# Weight tensor order — the ABI between aot.py, weights.bin and the Rust
# runtime. Per layer: attn_norm, wq, wk, wv, wo, ffn_norm, w_gate, w_up,
# w_down; then final_norm; embedding last (tied LM head).
PER_LAYER_WEIGHTS = [
    "attn_norm", "wq", "wk", "wv", "wo", "ffn_norm", "w_gate", "w_up", "w_down",
]


def weight_specs(cfg: ModelConfig) -> list[tuple[str, tuple[int, ...]]]:
    """Ordered (name, shape) list of every weight tensor."""
    specs: list[tuple[str, tuple[int, ...]]] = []
    h, qd, kvd, f = cfg.hidden, cfg.q_dim, cfg.kv_dim, cfg.ffn_hidden
    shapes = {
        "attn_norm": (h,),
        "wq": (h, qd),
        "wk": (h, kvd),
        "wv": (h, kvd),
        "wo": (qd, h),
        "ffn_norm": (h,),
        "w_gate": (h, f),
        "w_up": (h, f),
        "w_down": (f, h),
    }
    for layer in range(cfg.num_layers):
        for w in PER_LAYER_WEIGHTS:
            specs.append((f"layer{layer}.{w}", shapes[w]))
    specs.append(("final_norm", (h,)))
    specs.append(("embedding", (cfg.vocab, h)))
    return specs


def init_weights(cfg: ModelConfig, seed: int = 0) -> list[np.ndarray]:
    """Random but well-scaled weights (truncated-normal-ish via clip)."""
    rng = np.random.default_rng(seed)
    out = []
    for name, shape in weight_specs(cfg):
        if name.endswith("norm"):
            w = np.ones(shape, np.float32)
        else:
            fan_in = shape[0] if len(shape) == 2 else cfg.hidden
            std = 1.0 / np.sqrt(fan_in)
            w = np.clip(
                rng.normal(0.0, std, size=shape), -3 * std, 3 * std
            ).astype(np.float32)
        out.append(w)
    return out


def _unpack(cfg: ModelConfig, weights: list) -> tuple[list[dict], jnp.ndarray, jnp.ndarray]:
    """Split the flat ABI-ordered weight list into per-layer dicts."""
    n = len(PER_LAYER_WEIGHTS)
    layers = []
    for i in range(cfg.num_layers):
        chunk = weights[i * n : (i + 1) * n]
        layers.append(dict(zip(PER_LAYER_WEIGHTS, chunk)))
    final_norm = weights[cfg.num_layers * n]
    embedding = weights[cfg.num_layers * n + 1]
    return layers, final_norm, embedding


def _block_decode(cfg, layer, x, k_cache_l, v_cache_l, kv_lens, use_pallas):
    """One transformer block for a decode step.

    x: [B, hidden]; k/v_cache_l: [B, S, hkv, dh]; kv_lens: [B].
    Returns (x', k_cache_l', v_cache_l').
    """
    b = x.shape[0]
    h = ref.rms_norm(x, layer["attn_norm"])
    q = (h @ layer["wq"]).reshape(b, cfg.num_q_heads, cfg.head_dim)
    k = (h @ layer["wk"]).reshape(b, cfg.num_kv_heads, cfg.head_dim)
    v = (h @ layer["wv"]).reshape(b, cfg.num_kv_heads, cfg.head_dim)
    # RoPE at each row's own position (kv_lens = next index).
    q = _rope_rows(q, kv_lens, cfg.rope_theta)
    k = _rope_rows(k, kv_lens, cfg.rope_theta)
    # Append to cache at position kv_lens[i] per row.
    k_cache_l = _scatter_rows(k_cache_l, k, kv_lens)
    v_cache_l = _scatter_rows(v_cache_l, v, kv_lens)
    new_lens = kv_lens + 1
    if use_pallas:
        # Whole-cache KV block and full-width FFN tiles: the small
        # model's blocks fit VMEM outright, and fewer grid steps slash
        # the interpret-mode loop overhead on CPU (EXPERIMENTS.md §Perf).
        attn = gqa_decode_attention_pallas(
            q, k_cache_l, v_cache_l, new_lens, block_l=cfg.max_seq_len
        )
    else:
        attn = ref.gqa_decode_attention(q, k_cache_l, v_cache_l, new_lens)
    x = x + attn.reshape(b, cfg.q_dim) @ layer["wo"]
    h2 = ref.rms_norm(x, layer["ffn_norm"])
    if use_pallas:
        ffn = swiglu_ffn_pallas(
            h2, layer["w_gate"], layer["w_up"], layer["w_down"],
            block_m=max(8, b), block_f=cfg.ffn_hidden,
        )
    else:
        ffn = ref.swiglu_ffn(h2, layer["w_gate"], layer["w_up"], layer["w_down"])
    return x + ffn, k_cache_l, v_cache_l


def _rope_rows(x: jnp.ndarray, positions: jnp.ndarray, theta: float) -> jnp.ndarray:
    """RoPE for one token per row: x [B, heads, dh], positions [B]."""
    dh = x.shape[-1]
    half = dh // 2
    freqs = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    angles = positions[:, None].astype(jnp.float32) * freqs[None, :]  # [B, half]
    cos = jnp.cos(angles)[:, None, :]
    sin = jnp.sin(angles)[:, None, :]
    x1, x2 = x[..., :half], x[..., half:]
    return jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1).astype(x.dtype)


def _scatter_rows(cache: jnp.ndarray, new: jnp.ndarray, idx: jnp.ndarray) -> jnp.ndarray:
    """cache[i, idx[i]] = new[i] — per-row dynamic_update_slice.

    cache: [B, S, hkv, dh]; new: [B, hkv, dh]; idx: [B] int32.
    """
    def upd(c, n, i):
        return jax.lax.dynamic_update_slice(c, n[None], (i, 0, 0))

    return jax.vmap(upd)(cache, new, idx)


def decode_step(
    cfg: ModelConfig,
    weights: list,
    tokens: jnp.ndarray,   # [B] int32 — previous tokens
    kv_lens: jnp.ndarray,  # [B] int32 — current valid KV length per row
    k_cache: jnp.ndarray,  # [L, B, S, hkv, dh]
    v_cache: jnp.ndarray,  # [L, B, S, hkv, dh]
    use_pallas: bool = True,
):
    """One decode iteration for B requests.

    Returns (next_tokens [B] i32, k_cache', v_cache').
    """
    layers, final_norm, embedding = _unpack(cfg, weights)
    x = embedding[tokens]  # [B, hidden]
    new_k, new_v = [], []
    for li, layer in enumerate(layers):
        x, kc, vc = _block_decode(
            cfg, layer, x, k_cache[li], v_cache[li], kv_lens, use_pallas
        )
        new_k.append(kc)
        new_v.append(vc)
    x = ref.rms_norm(x, final_norm)
    logits = x @ embedding.T  # tied head: [B, vocab]
    next_tokens = jnp.argmax(logits, axis=-1).astype(jnp.int32)
    return next_tokens, jnp.stack(new_k), jnp.stack(new_v)


def prefill_chunk(
    cfg: ModelConfig,
    weights: list,
    tokens: jnp.ndarray,     # [T] int32 — the chunk's tokens (padded)
    start_pos: jnp.ndarray,  # scalar i32 — absolute position of tokens[0]
    chunk_len: jnp.ndarray,  # scalar i32 — real (unpadded) token count
    k_cache: jnp.ndarray,    # [L, S, hkv, dh] — this request's cache
    v_cache: jnp.ndarray,
    use_pallas: bool = True,
):
    """One chunk of one request's prefill.

    Writes the chunk's KV into the cache and returns
    (first_token [] i32, k_cache', v_cache'). `first_token` is the argmax
    over the last *real* token's logits — only meaningful on the final
    chunk of the prompt.
    """
    layers, final_norm, embedding = _unpack(cfg, weights)
    t = tokens.shape[0]
    positions = start_pos + jnp.arange(t, dtype=jnp.int32)
    x = embedding[tokens]  # [T, hidden]
    kv_len = start_pos + chunk_len  # valid KV after this chunk
    new_k, new_v = [], []
    for li, layer in enumerate(layers):
        h = ref.rms_norm(x, layer["attn_norm"])
        q = (h @ layer["wq"]).reshape(t, cfg.num_q_heads, cfg.head_dim)
        k = (h @ layer["wk"]).reshape(t, cfg.num_kv_heads, cfg.head_dim)
        v = (h @ layer["wv"]).reshape(t, cfg.num_kv_heads, cfg.head_dim)
        q = ref.rope(q, positions, cfg.rope_theta)
        k = ref.rope(k, positions, cfg.rope_theta)
        kc = jax.lax.dynamic_update_slice(k_cache[li], k, (start_pos, 0, 0))
        vc = jax.lax.dynamic_update_slice(v_cache[li], v, (start_pos, 0, 0))
        if use_pallas:
            attn = causal_prefill_attention_pallas(
                q, kc, vc, start_pos, block_q=min(128, t), block_k=cfg.max_seq_len
            )
        else:
            attn = ref.causal_prefill_attention(q, kc, vc, start_pos)
        # Keys beyond kv_len are garbage (padded rows); queries beyond
        # chunk_len produce garbage outputs which we never read. Causality
        # keeps real queries from seeing padded keys (they sit at higher
        # positions).
        x = x + attn.reshape(t, cfg.q_dim) @ layer["wo"]
        h2 = ref.rms_norm(x, layer["ffn_norm"])
        if use_pallas:
            ffn = swiglu_ffn_pallas(
                h2, layer["w_gate"], layer["w_up"], layer["w_down"],
                block_m=min(128, t), block_f=cfg.ffn_hidden,
            )
        else:
            ffn = ref.swiglu_ffn(h2, layer["w_gate"], layer["w_up"], layer["w_down"])
        x = x + ffn
        new_k.append(kc)
        new_v.append(vc)
    x = ref.rms_norm(x, final_norm)
    last = jnp.clip(chunk_len - 1, 0, t - 1)
    logits = x[last] @ embedding.T  # [vocab]
    first_token = jnp.argmax(logits).astype(jnp.int32)
    _ = kv_len
    return first_token, jnp.stack(new_k), jnp.stack(new_v)


def make_decode_fn(cfg: ModelConfig, use_pallas: bool = True):
    """Close over cfg; returns f(weights..., tokens, kv_lens, kc, vc)."""

    @functools.wraps(decode_step)
    def fn(tokens, kv_lens, k_cache, v_cache, *weights):
        return decode_step(cfg, list(weights), tokens, kv_lens, k_cache, v_cache, use_pallas)

    return fn


def make_prefill_fn(cfg: ModelConfig, use_pallas: bool = True):
    @functools.wraps(prefill_chunk)
    def fn(tokens, start_pos, chunk_len, k_cache, v_cache, *weights):
        return prefill_chunk(
            cfg, list(weights), tokens, start_pos, chunk_len, k_cache, v_cache, use_pallas
        )

    return fn


def kv_cache_shape_decode(cfg: ModelConfig, batch: int) -> tuple[int, ...]:
    return (cfg.num_layers, batch, cfg.max_seq_len, cfg.num_kv_heads, cfg.head_dim)


def kv_cache_shape_prefill(cfg: ModelConfig) -> tuple[int, ...]:
    return (cfg.num_layers, cfg.max_seq_len, cfg.num_kv_heads, cfg.head_dim)
