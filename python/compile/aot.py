"""AOT lowering: JAX → HLO text artifacts for the Rust PJRT runtime.

Emits, under ``artifacts/``:

* ``decode_b{B}.hlo.txt``  — decode step for each batch bucket B
* ``prefill_t{T}.hlo.txt`` — prefill chunk for each chunk bucket T
* ``weights.bin``          — all weight tensors, f32 little-endian,
  concatenated in ABI order (model.weight_specs)
* ``manifest.json``        — model config, buckets, weight table, and
  the argument/result ABI of every entry point

HLO *text* (not ``.serialize()``) is the interchange format: jax ≥ 0.5
emits HloModuleProto with 64-bit instruction ids which xla_extension
0.5.1 rejects; the text parser reassigns ids (see
/opt/xla-example/README.md).

Run via ``make artifacts``; a no-op if inputs are unchanged (make
handles staleness). Python never runs at serving time.
"""

from __future__ import annotations

import argparse
import hashlib
import json
import os

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from . import model as M

DECODE_BATCH_BUCKETS = [1, 2, 4, 8]
PREFILL_CHUNK_BUCKETS = [64, 128]


def to_hlo_text(lowered) -> str:
    """StableHLO → XlaComputation → HLO text (return_tuple for rust)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_decode(cfg: M.ModelConfig, batch: int, use_pallas: bool = True) -> str:
    fn = M.make_decode_fn(cfg, use_pallas=use_pallas)
    kv_shape = M.kv_cache_shape_decode(cfg, batch)
    args = [
        jax.ShapeDtypeStruct((batch,), jnp.int32),   # tokens
        jax.ShapeDtypeStruct((batch,), jnp.int32),   # kv_lens
        jax.ShapeDtypeStruct(kv_shape, jnp.float32), # k_cache
        jax.ShapeDtypeStruct(kv_shape, jnp.float32), # v_cache
    ] + [jax.ShapeDtypeStruct(s, jnp.float32) for _, s in M.weight_specs(cfg)]
    lowered = jax.jit(fn).lower(*args)
    return to_hlo_text(lowered)


def lower_prefill(cfg: M.ModelConfig, chunk: int, use_pallas: bool = True) -> str:
    fn = M.make_prefill_fn(cfg, use_pallas=use_pallas)
    kv_shape = M.kv_cache_shape_prefill(cfg)
    args = [
        jax.ShapeDtypeStruct((chunk,), jnp.int32),   # tokens
        jax.ShapeDtypeStruct((), jnp.int32),         # start_pos
        jax.ShapeDtypeStruct((), jnp.int32),         # chunk_len
        jax.ShapeDtypeStruct(kv_shape, jnp.float32), # k_cache
        jax.ShapeDtypeStruct(kv_shape, jnp.float32), # v_cache
    ] + [jax.ShapeDtypeStruct(s, jnp.float32) for _, s in M.weight_specs(cfg)]
    lowered = jax.jit(fn).lower(*args)
    return to_hlo_text(lowered)


def write_weights(cfg: M.ModelConfig, out_dir: str, seed: int) -> list[dict]:
    """weights.bin + table of (name, shape, byte offset, length)."""
    weights = M.init_weights(cfg, seed=seed)
    table = []
    offset = 0
    path = os.path.join(out_dir, "weights.bin")
    with open(path, "wb") as f:
        for (name, shape), w in zip(M.weight_specs(cfg), weights):
            raw = np.ascontiguousarray(w, dtype="<f4").tobytes()
            f.write(raw)
            table.append(
                {
                    "name": name,
                    "shape": list(shape),
                    "offset": offset,
                    "bytes": len(raw),
                }
            )
            offset += len(raw)
    return table


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--seed", type=int, default=0, help="weight init seed")
    ap.add_argument(
        "--no-pallas",
        action="store_true",
        help="lower the pure-jnp reference model instead (debug only)",
    )
    args = ap.parse_args()
    cfg = M.SMALL_CONFIG
    os.makedirs(args.out_dir, exist_ok=True)
    use_pallas = not args.no_pallas

    entries = []
    for b in DECODE_BATCH_BUCKETS:
        text = lower_decode(cfg, b, use_pallas)
        fname = f"decode_b{b}.hlo.txt"
        with open(os.path.join(args.out_dir, fname), "w") as f:
            f.write(text)
        entries.append(
            {
                "kind": "decode",
                "batch": b,
                "file": fname,
                "sha256": hashlib.sha256(text.encode()).hexdigest(),
            }
        )
        print(f"[aot] {fname}: {len(text) / 1e6:.2f} MB HLO text")
    for t in PREFILL_CHUNK_BUCKETS:
        text = lower_prefill(cfg, t, use_pallas)
        fname = f"prefill_t{t}.hlo.txt"
        with open(os.path.join(args.out_dir, fname), "w") as f:
            f.write(text)
        entries.append(
            {
                "kind": "prefill",
                "chunk": t,
                "file": fname,
                "sha256": hashlib.sha256(text.encode()).hexdigest(),
            }
        )
        print(f"[aot] {fname}: {len(text) / 1e6:.2f} MB HLO text")

    weight_table = write_weights(cfg, args.out_dir, args.seed)

    manifest = {
        "model": {
            "name": cfg.name,
            "num_layers": cfg.num_layers,
            "hidden": cfg.hidden,
            "num_q_heads": cfg.num_q_heads,
            "num_kv_heads": cfg.num_kv_heads,
            "head_dim": cfg.head_dim,
            "ffn_hidden": cfg.ffn_hidden,
            "vocab": cfg.vocab,
            "max_seq_len": cfg.max_seq_len,
        },
        "use_pallas": use_pallas,
        "decode_batch_buckets": DECODE_BATCH_BUCKETS,
        "prefill_chunk_buckets": PREFILL_CHUNK_BUCKETS,
        "executables": entries,
        "weights": {"file": "weights.bin", "dtype": "f32le", "tensors": weight_table},
        "abi": {
            "decode": {
                "args": ["tokens[i32,B]", "kv_lens[i32,B]",
                          "k_cache[f32,L,B,S,HKV,DH]", "v_cache[f32,L,B,S,HKV,DH]",
                          "...weights (ABI order)"],
                "results": ["next_tokens[i32,B]", "k_cache'", "v_cache'"],
            },
            "prefill": {
                "args": ["tokens[i32,T]", "start_pos[i32]", "chunk_len[i32]",
                          "k_cache[f32,L,S,HKV,DH]", "v_cache[f32,L,S,HKV,DH]",
                          "...weights (ABI order)"],
                "results": ["first_token[i32]", "k_cache'", "v_cache'"],
            },
        },
    }
    with open(os.path.join(args.out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=2)
    print(f"[aot] wrote manifest with {len(entries)} executables")


if __name__ == "__main__":
    main()
