"""Build-time compile path (Layer 1 + Layer 2).

Never imported at serving time: `make artifacts` runs `compile.aot`
once, writing HLO text + a manifest under `artifacts/`; the Rust binary
is self-contained afterwards.
"""
