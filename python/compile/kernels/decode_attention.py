"""GQA decode attention as a Pallas kernel (flash-decoding style).

TPU adaptation of the paper's GPU decode-attention hot spot (DESIGN.md
§Hardware-Adaptation): instead of CUDA threadblocks staging KV tiles in
shared memory, the KV cache is streamed HBM→VMEM in ``block_l``-sized
BlockSpec blocks; the Q·Kᵀ and P·V contractions are MXU-shaped
``[group, head_dim] × [head_dim, block_l]`` matmuls; the softmax is
computed online with a (m, l, acc) carry held in VMEM scratch across KV
blocks — the grid's innermost axis iterates KV blocks sequentially, so
the carry persists exactly like a flash-decoding register accumulator.

Grid: ``(batch, num_kv_heads, num_kv_blocks)``.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _decode_attn_kernel(
    kv_len_ref,  # [1]            int32 — valid KV prefix for this row
    q_ref,       # [1, 1, group, head_dim]
    k_ref,       # [1, 1, block_l, head_dim]
    v_ref,       # [1, 1, block_l, head_dim]
    o_ref,       # [1, 1, group, head_dim]
    m_ref,       # scratch [group, 1]   running max
    l_ref,       # scratch [group, 1]   running denominator
    acc_ref,     # scratch [group, head_dim] running numerator
    *,
    block_l: int,
    scale: float,
):
    kv_block = pl.program_id(2)
    num_blocks = pl.num_programs(2)

    @pl.when(kv_block == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0, 0]  # [group, head_dim]
    k = k_ref[0, 0]  # [block_l, head_dim]
    v = v_ref[0, 0]  # [block_l, head_dim]

    # MXU contraction: [group, dh] x [dh, block_l] -> [group, block_l]
    s = jax.lax.dot_general(
        q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    ) * scale

    # Mask out positions beyond the row's valid KV length.
    kv_len = kv_len_ref[0]
    base = kv_block * block_l
    pos = base + jax.lax.broadcasted_iota(jnp.int32, s.shape, dimension=1)
    s = jnp.where(pos < kv_len, s, NEG_INF)

    # Online softmax update.
    m_prev = m_ref[...]                      # [group, 1]
    m_cur = jnp.max(s, axis=1, keepdims=True)
    m_new = jnp.maximum(m_prev, m_cur)
    alpha = jnp.exp(m_prev - m_new)          # rescale of old accumulator
    p = jnp.exp(s - m_new)                   # [group, block_l]
    l_ref[...] = l_ref[...] * alpha + jnp.sum(p, axis=1, keepdims=True)
    acc_ref[...] = acc_ref[...] * alpha + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )
    m_ref[...] = m_new

    @pl.when(kv_block == num_blocks - 1)
    def _finish():
        # Guard against fully-masked rows (kv_len == 0 can't happen for
        # real requests, but keep the kernel total).
        denom = jnp.where(l_ref[...] == 0.0, 1.0, l_ref[...])
        o_ref[0, 0] = (acc_ref[...] / denom).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block_l", "interpret"))
def gqa_decode_attention_pallas(
    q: jnp.ndarray,        # [batch, num_q_heads, head_dim]
    k_cache: jnp.ndarray,  # [batch, max_len, num_kv_heads, head_dim]
    v_cache: jnp.ndarray,  # [batch, max_len, num_kv_heads, head_dim]
    kv_lens: jnp.ndarray,  # [batch] int32
    *,
    block_l: int = 128,
    interpret: bool = True,
) -> jnp.ndarray:
    """Pallas GQA decode attention. Returns [batch, num_q_heads, head_dim]."""
    b, hq, dh = q.shape
    _, max_len, hkv, _ = k_cache.shape
    assert hq % hkv == 0
    group = hq // hkv
    scale = 1.0 / (dh ** 0.5)

    # Head-major KV layout so the KV-length axis is blockable.
    k_t = jnp.swapaxes(k_cache, 1, 2)  # [b, hkv, max_len, dh]
    v_t = jnp.swapaxes(v_cache, 1, 2)
    # Pad KV length to a block multiple (masked inside the kernel).
    padded = (max_len + block_l - 1) // block_l * block_l
    if padded != max_len:
        pad = ((0, 0), (0, 0), (0, padded - max_len), (0, 0))
        k_t = jnp.pad(k_t, pad)
        v_t = jnp.pad(v_t, pad)
    num_blocks = padded // block_l

    qg = q.reshape(b, hkv, group, dh)

    kernel = functools.partial(_decode_attn_kernel, block_l=block_l, scale=scale)
    out = pl.pallas_call(
        kernel,
        grid=(b, hkv, num_blocks),
        in_specs=[
            pl.BlockSpec((1,), lambda i, j, l: (i,)),
            pl.BlockSpec((1, 1, group, dh), lambda i, j, l: (i, j, 0, 0)),
            pl.BlockSpec((1, 1, block_l, dh), lambda i, j, l: (i, j, l, 0)),
            pl.BlockSpec((1, 1, block_l, dh), lambda i, j, l: (i, j, l, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, group, dh), lambda i, j, l: (i, j, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((b, hkv, group, dh), q.dtype),
        scratch_shapes=[
            # (m, l, acc) carried across the KV-block axis.
            pltpu.VMEM((group, 1), jnp.float32),
            pltpu.VMEM((group, 1), jnp.float32),
            pltpu.VMEM((group, dh), jnp.float32),
        ],
        interpret=interpret,
    )(kv_lens.astype(jnp.int32), qg, k_t, v_t)
    return out.reshape(b, hq, dh)
