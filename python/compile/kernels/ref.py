"""Pure-jnp oracles for the Pallas kernels.

These are the correctness ground truth: every kernel in this package is
checked against the corresponding function here by pytest (exact math,
no Pallas, no tiling). They are also used directly by model.py when
``use_pallas=False`` is requested (e.g. for HLO-size comparisons).
"""

from __future__ import annotations

import jax.numpy as jnp


def rms_norm(x: jnp.ndarray, gain: jnp.ndarray, eps: float = 1e-6) -> jnp.ndarray:
    """RMSNorm over the last axis."""
    scale = jnp.reciprocal(jnp.sqrt(jnp.mean(x * x, axis=-1, keepdims=True) + eps))
    return x * scale * gain


def swiglu_ffn(
    x: jnp.ndarray,
    w_gate: jnp.ndarray,
    w_up: jnp.ndarray,
    w_down: jnp.ndarray,
) -> jnp.ndarray:
    """SwiGLU feed-forward: down( silu(x @ gate) * (x @ up) ).

    x: [tokens, hidden]; w_gate/w_up: [hidden, ffn]; w_down: [ffn, hidden].
    """
    g = x @ w_gate
    u = x @ w_up
    act = g * jnp.reciprocal(1.0 + jnp.exp(-g))  # SiLU
    return (act * u) @ w_down


def gqa_decode_attention(
    q: jnp.ndarray,
    k_cache: jnp.ndarray,
    v_cache: jnp.ndarray,
    kv_lens: jnp.ndarray,
    scale: float | None = None,
) -> jnp.ndarray:
    """Grouped-query decode attention over a padded KV cache.

    q:        [batch, num_q_heads, head_dim]      (one new token per seq)
    k_cache:  [batch, max_len, num_kv_heads, head_dim]
    v_cache:  [batch, max_len, num_kv_heads, head_dim]
    kv_lens:  [batch] int32 — valid prefix length per sequence
    returns:  [batch, num_q_heads, head_dim]
    """
    b, hq, dh = q.shape
    _, max_len, hkv, _ = k_cache.shape
    assert hq % hkv == 0, "q heads must be a multiple of kv heads"
    group = hq // hkv
    if scale is None:
        scale = dh ** -0.5

    # Broadcast KV heads across their query group.
    k = jnp.swapaxes(k_cache, 1, 2)  # [b, hkv, max_len, dh]
    v = jnp.swapaxes(v_cache, 1, 2)
    qg = q.reshape(b, hkv, group, dh)
    scores = jnp.einsum("bhgd,bhld->bhgl", qg, k) * scale
    mask = jnp.arange(max_len)[None, None, None, :] < kv_lens[:, None, None, None]
    scores = jnp.where(mask, scores, jnp.finfo(scores.dtype).min)
    probs = jnp.exp(scores - scores.max(axis=-1, keepdims=True))
    probs = jnp.where(mask, probs, 0.0)
    probs = probs / probs.sum(axis=-1, keepdims=True)
    out = jnp.einsum("bhgl,bhld->bhgd", probs, v)
    return out.reshape(b, hq, dh)


def causal_prefill_attention(
    q: jnp.ndarray,
    k: jnp.ndarray,
    v: jnp.ndarray,
    start_pos: int = 0,
    scale: float | None = None,
) -> jnp.ndarray:
    """Causal self-attention for a (chunked) prefill with GQA.

    The chunk's queries occupy absolute positions
    ``[start_pos, start_pos + chunk)``; keys/values cover positions
    ``[0, kv_len)`` with ``kv_len = start_pos + chunk`` (prior context's
    KV is already cached from earlier chunks).

    q: [chunk, num_q_heads, head_dim]
    k: [kv_len, num_kv_heads, head_dim]
    v: [kv_len, num_kv_heads, head_dim]
    returns: [chunk, num_q_heads, head_dim]
    """
    t, hq, dh = q.shape
    s, hkv, _ = k.shape
    group = hq // hkv
    if scale is None:
        scale = dh ** -0.5
    qg = q.reshape(t, hkv, group, dh)
    scores = jnp.einsum("thgd,shd->hgts", qg, k) * scale
    q_pos = start_pos + jnp.arange(t)
    k_pos = jnp.arange(s)
    mask = k_pos[None, :] <= q_pos[:, None]  # causal: key pos ≤ query pos
    scores = jnp.where(mask[None, None, :, :], scores, jnp.finfo(scores.dtype).min)
    probs = jnp.exp(scores - scores.max(axis=-1, keepdims=True))
    probs = probs / probs.sum(axis=-1, keepdims=True)
    out = jnp.einsum("hgts,shd->thgd", probs, v)
    return out.reshape(t, hq, dh)


def rope(x: jnp.ndarray, positions: jnp.ndarray, theta: float = 10000.0) -> jnp.ndarray:
    """Rotary position embedding (split-halves convention).

    x: [seq, heads, head_dim] (or any leading dims before heads);
    positions: [seq] int32 absolute positions.
    """
    dh = x.shape[-1]
    half = dh // 2
    freqs = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    angles = positions[:, None].astype(jnp.float32) * freqs[None, :]  # [seq, half]
    cos = jnp.cos(angles)[:, None, :]  # [seq, 1, half]
    sin = jnp.sin(angles)[:, None, :]
    x1 = x[..., :half]
    x2 = x[..., half:]
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)
