"""Layer-1 Pallas kernels.

Three kernels cover the serving hot path of the LLaMA-style model:

* :mod:`decode_attention` — GQA decode attention (flash-decoding style,
  KV streamed in blocks with an online softmax carry).
* :mod:`prefill_attention` — blocked causal (chunked-)prefill attention.
* :mod:`fused_ffn` — SwiGLU FFN with the gate/up/down projections fused
  in one kernel so activations never round-trip to HBM.

All kernels are lowered with ``interpret=True``: the CPU PJRT plugin
cannot execute Mosaic custom-calls, so interpret mode is the correctness
path; the TPU mapping (VMEM blocking, MXU-shaped matmuls) is preserved
structurally and its VMEM/MXU budget is analyzed in EXPERIMENTS.md §Perf.

``ref.py`` holds the pure-jnp oracles used by pytest.
"""

from . import ref  # noqa: F401
from .decode_attention import gqa_decode_attention_pallas  # noqa: F401
from .prefill_attention import causal_prefill_attention_pallas  # noqa: F401
from .fused_ffn import swiglu_ffn_pallas  # noqa: F401
