"""Fused SwiGLU FFN as a Pallas kernel.

Computes ``down( silu(x @ Wg) * (x @ Wu) )`` with all three projections
fused: the ffn dimension is tiled into ``block_f`` slices, and each grid
step contracts one slice end-to-end — gate, up, activation, and its
partial down-projection — accumulating the output block in VMEM scratch.
The ``[block_f, hidden]``-sized activation tile therefore never leaves
VMEM (on a GPU this is the shared-memory-resident epilogue fusion the
paper's engines get from fused MLP kernels).

Grid: ``(num_m_blocks, num_f_blocks)``; the f axis is innermost so the
output accumulator carries across it.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _ffn_kernel(
    x_ref,    # [block_m, hidden]
    wg_ref,   # [hidden, block_f]
    wu_ref,   # [hidden, block_f]
    wd_ref,   # [block_f, hidden]
    o_ref,    # [block_m, hidden]
    acc_ref,  # scratch [block_m, hidden] f32
):
    f_block = pl.program_id(1)
    num_f_blocks = pl.num_programs(1)

    @pl.when(f_block == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    x = x_ref[...]
    g = jax.lax.dot_general(
        x, wg_ref[...], (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )
    u = jax.lax.dot_general(
        x, wu_ref[...], (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )
    act = g * jax.lax.logistic(g)  # SiLU
    h = act * u  # [block_m, block_f]
    acc_ref[...] += jax.lax.dot_general(
        h, wd_ref[...], (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )

    @pl.when(f_block == num_f_blocks - 1)
    def _finish():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


@functools.partial(
    jax.jit, static_argnames=("block_m", "block_f", "interpret")
)
def swiglu_ffn_pallas(
    x: jnp.ndarray,       # [tokens, hidden]
    w_gate: jnp.ndarray,  # [hidden, ffn]
    w_up: jnp.ndarray,    # [hidden, ffn]
    w_down: jnp.ndarray,  # [ffn, hidden]
    *,
    block_m: int = 64,
    block_f: int = 256,
    interpret: bool = True,
) -> jnp.ndarray:
    """Pallas fused SwiGLU FFN. Returns [tokens, hidden]."""
    t, h = x.shape
    f = w_gate.shape[1]
    assert w_up.shape == (h, f) and w_down.shape == (f, h)

    t_pad = (t + block_m - 1) // block_m * block_m
    f_pad = (f + block_f - 1) // block_f * block_f
    xp = jnp.pad(x, ((0, t_pad - t), (0, 0))) if t_pad != t else x
    if f_pad != f:
        # Zero-padding the ffn axis is exact: silu(0)*0 = 0 contributes
        # nothing to the down-projection.
        w_gate = jnp.pad(w_gate, ((0, 0), (0, f_pad - f)))
        w_up = jnp.pad(w_up, ((0, 0), (0, f_pad - f)))
        w_down = jnp.pad(w_down, ((0, f_pad - f), (0, 0)))

    out = pl.pallas_call(
        _ffn_kernel,
        grid=(t_pad // block_m, f_pad // block_f),
        in_specs=[
            pl.BlockSpec((block_m, h), lambda i, j: (i, 0)),
            pl.BlockSpec((h, block_f), lambda i, j: (0, j)),
            pl.BlockSpec((h, block_f), lambda i, j: (0, j)),
            pl.BlockSpec((block_f, h), lambda i, j: (j, 0)),
        ],
        out_specs=pl.BlockSpec((block_m, h), lambda i, j: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((t_pad, h), x.dtype),
        scratch_shapes=[pltpu.VMEM((block_m, h), jnp.float32)],
        interpret=interpret,
    )(xp, w_gate, w_up, w_down)
    return out[:t]
