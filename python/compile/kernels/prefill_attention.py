"""Blocked causal (chunked-)prefill attention as a Pallas kernel.

Flash-attention-style TPU mapping: queries of the current prefill chunk
are tiled into ``block_q`` rows; keys/values (prior context + chunk) are
streamed in ``block_k`` blocks; the online-softmax carry (m, l, acc)
lives in VMEM scratch across the KV-block grid axis. The causal mask is
computed from absolute positions, so the kernel serves both full prefill
(``start_pos = 0``) and later chunks of a chunked prefill
(``start_pos > 0`` with earlier KV already cached).

Grid: ``(num_kv_heads, num_q_blocks, num_kv_blocks)``.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _prefill_attn_kernel(
    start_ref,   # [1] int32 — absolute position of the chunk's first query
    kv_len_ref,  # [1] int32 — total valid KV length (ctx + chunk)
    q_ref,       # [1, block_q, group, head_dim]
    k_ref,       # [1, block_k, head_dim]
    v_ref,       # [1, block_k, head_dim]
    o_ref,       # [1, block_q, group, head_dim]
    m_ref,       # scratch [block_q * group, 1]
    l_ref,       # scratch [block_q * group, 1]
    acc_ref,     # scratch [block_q * group, head_dim]
    *,
    block_q: int,
    block_k: int,
    scale: float,
):
    q_block = pl.program_id(1)
    kv_block = pl.program_id(2)
    num_kv_blocks = pl.num_programs(2)

    @pl.when(kv_block == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    bq, group, dh = q_ref.shape[1], q_ref.shape[2], q_ref.shape[3]
    q = q_ref[0].reshape(bq * group, dh)  # [rows, dh]
    k = k_ref[0]                          # [block_k, dh]
    v = v_ref[0]

    s = jax.lax.dot_general(
        q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    ) * scale  # [rows, block_k]

    # Causal + validity mask from absolute positions.
    start = start_ref[0]
    kv_len = kv_len_ref[0]
    row = jax.lax.broadcasted_iota(jnp.int32, s.shape, dimension=0)
    q_pos = start + q_block * block_q + row // group
    col = jax.lax.broadcasted_iota(jnp.int32, s.shape, dimension=1)
    k_pos = kv_block * block_k + col
    ok = (k_pos <= q_pos) & (k_pos < kv_len)
    s = jnp.where(ok, s, NEG_INF)

    m_prev = m_ref[...]
    m_cur = jnp.max(s, axis=1, keepdims=True)
    m_new = jnp.maximum(m_prev, m_cur)
    alpha = jnp.exp(m_prev - m_new)
    p = jnp.exp(s - m_new)
    p = jnp.where(ok, p, 0.0)  # rows fully masked keep exp(NEG_INF-m)=0
    l_ref[...] = l_ref[...] * alpha + jnp.sum(p, axis=1, keepdims=True)
    acc_ref[...] = acc_ref[...] * alpha + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )
    m_ref[...] = m_new

    @pl.when(kv_block == num_kv_blocks - 1)
    def _finish():
        denom = jnp.where(l_ref[...] == 0.0, 1.0, l_ref[...])
        out = (acc_ref[...] / denom).astype(o_ref.dtype)
        o_ref[0] = out.reshape(bq, group, dh)


@functools.partial(
    jax.jit, static_argnames=("block_q", "block_k", "interpret")
)
def causal_prefill_attention_pallas(
    q: jnp.ndarray,   # [chunk, num_q_heads, head_dim]
    k: jnp.ndarray,   # [kv_len, num_kv_heads, head_dim]
    v: jnp.ndarray,   # [kv_len, num_kv_heads, head_dim]
    start_pos,        # int32 scalar — absolute position of q[0]
    *,
    block_q: int = 64,
    block_k: int = 128,
    interpret: bool = True,
) -> jnp.ndarray:
    """Pallas chunked-prefill attention. Returns [chunk, hq, head_dim]."""
    t, hq, dh = q.shape
    s_len, hkv, _ = k.shape
    assert hq % hkv == 0
    group = hq // hkv
    scale = 1.0 / (dh ** 0.5)

    # Pad chunk and KV length to block multiples (masked in-kernel).
    t_pad = (t + block_q - 1) // block_q * block_q
    s_pad = (s_len + block_k - 1) // block_k * block_k
    qg = q.reshape(t, hkv, group, dh)
    if t_pad != t:
        qg = jnp.pad(qg, ((0, t_pad - t), (0, 0), (0, 0), (0, 0)))
    k_t = jnp.swapaxes(k, 0, 1)  # [hkv, kv_len, dh]
    v_t = jnp.swapaxes(v, 0, 1)
    if s_pad != s_len:
        k_t = jnp.pad(k_t, ((0, 0), (0, s_pad - s_len), (0, 0)))
        v_t = jnp.pad(v_t, ((0, 0), (0, s_pad - s_len), (0, 0)))
    qg = jnp.swapaxes(qg, 0, 1)  # [hkv, t_pad, group, dh]

    kernel = functools.partial(
        _prefill_attn_kernel, block_q=block_q, block_k=block_k, scale=scale
    )
    start = jnp.asarray([start_pos], jnp.int32)
    kv_len = jnp.asarray([s_len], jnp.int32)
    out = pl.pallas_call(
        kernel,
        grid=(hkv, t_pad // block_q, s_pad // block_k),
        in_specs=[
            pl.BlockSpec((1,), lambda h, i, l: (0,)),
            pl.BlockSpec((1,), lambda h, i, l: (0,)),
            pl.BlockSpec((1, block_q, group, dh), lambda h, i, l: (h, i, 0, 0)),
            pl.BlockSpec((1, block_k, dh), lambda h, i, l: (h, l, 0)),
            pl.BlockSpec((1, block_k, dh), lambda h, i, l: (h, l, 0)),
        ],
        out_specs=pl.BlockSpec(
            (1, block_q, group, dh), lambda h, i, l: (h, i, 0, 0)
        ),
        out_shape=jax.ShapeDtypeStruct((hkv, t_pad, group, dh), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q * group, 1), jnp.float32),
            pltpu.VMEM((block_q * group, 1), jnp.float32),
            pltpu.VMEM((block_q * group, dh), jnp.float32),
        ],
        interpret=interpret,
    )(start, kv_len, qg, k_t, v_t)
    out = jnp.swapaxes(out, 0, 1)[:t]  # [t, hkv, group, dh]
    return out.reshape(t, hq, dh)
