"""L2 correctness: model composition — chunked prefill + batched decode
with Pallas kernels must match the pure-jnp oracle path exactly
(greedy tokens) and closely (KV cache values)."""

import numpy as np
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from compile import model as M

CFG = M.SMALL_CONFIG


@pytest.fixture(scope="module")
def weights():
    return [jnp.asarray(w) for w in M.init_weights(CFG, seed=1)]


def full_prefill(weights, prompt, use_pallas):
    kc = jnp.zeros(M.kv_cache_shape_prefill(CFG), jnp.float32)
    vc = jnp.zeros_like(kc)
    return M.prefill_chunk(
        CFG, weights, prompt, jnp.int32(0), jnp.int32(prompt.shape[0]), kc, vc,
        use_pallas=use_pallas,
    )


def test_weight_specs_count_and_shapes():
    specs = M.weight_specs(CFG)
    assert len(specs) == CFG.num_layers * len(M.PER_LAYER_WEIGHTS) + 2
    names = [n for n, _ in specs]
    assert names[-1] == "embedding"
    assert names[-2] == "final_norm"
    total = sum(int(np.prod(s)) for _, s in specs)
    assert 1_000_000 < total < 10_000_000  # "small" model


def test_prefill_pallas_matches_oracle(weights):
    rng = np.random.default_rng(0)
    prompt = jnp.asarray(rng.integers(0, CFG.vocab, size=(53,)), jnp.int32)
    t_ref, kc_ref, vc_ref = full_prefill(weights, prompt, use_pallas=False)
    t_pal, kc_pal, vc_pal = full_prefill(weights, prompt, use_pallas=True)
    assert int(t_ref) == int(t_pal)
    np.testing.assert_allclose(kc_ref[:, :53], kc_pal[:, :53], atol=2e-4, rtol=2e-4)
    np.testing.assert_allclose(vc_ref[:, :53], vc_pal[:, :53], atol=2e-4, rtol=2e-4)


@settings(max_examples=8, deadline=None)
@given(
    p=st.integers(2, 150),
    chunk=st.sampled_from([16, 64, 128]),
    seed=st.integers(0, 2**31),
)
def test_chunked_prefill_equals_full(p, chunk, seed):
    weights = [jnp.asarray(w) for w in M.init_weights(CFG, seed=1)]
    rng = np.random.default_rng(seed)
    prompt = jnp.asarray(rng.integers(0, CFG.vocab, size=(p,)), jnp.int32)
    t_full, kc_full, _ = full_prefill(weights, prompt, use_pallas=False)

    kc = jnp.zeros(M.kv_cache_shape_prefill(CFG), jnp.float32)
    vc = jnp.zeros_like(kc)
    pos = 0
    tok = None
    while pos < p:
        n = min(chunk, p - pos)
        padded = jnp.zeros((chunk,), jnp.int32).at[:n].set(prompt[pos : pos + n])
        tok, kc, vc = M.prefill_chunk(
            CFG, weights, padded, jnp.int32(pos), jnp.int32(n), kc, vc,
            use_pallas=True,
        )
        pos += n
    assert int(tok) == int(t_full)
    np.testing.assert_allclose(kc_full[:, :p], kc[:, :p], atol=3e-4, rtol=3e-4)


def test_batched_decode_matches_oracle_trajectory(weights):
    rng = np.random.default_rng(3)
    lens = [17, 40, 9]
    b = len(lens)
    kcd = jnp.zeros(M.kv_cache_shape_decode(CFG, b), jnp.float32)
    vcd = jnp.zeros_like(kcd)
    toks = []
    for i, p in enumerate(lens):
        prompt = jnp.asarray(rng.integers(0, CFG.vocab, size=(p,)), jnp.int32)
        t0, kc, vc = full_prefill(weights, prompt, use_pallas=False)
        kcd = kcd.at[:, i].set(kc)
        vcd = vcd.at[:, i].set(vc)
        toks.append(int(t0))
    state = {
        True: (jnp.asarray(toks, jnp.int32), jnp.asarray(lens, jnp.int32), kcd, vcd),
        False: (jnp.asarray(toks, jnp.int32), jnp.asarray(lens, jnp.int32), kcd, vcd),
    }
    for step in range(5):
        outs = {}
        for pal in (True, False):
            t, l, kc, vc = state[pal]
            t2, kc2, vc2 = M.decode_step(CFG, weights, t, l, kc, vc, use_pallas=pal)
            state[pal] = (t2, l + 1, kc2, vc2)
            outs[pal] = [int(x) for x in t2]
        assert outs[True] == outs[False], f"diverged at step {step}"


def test_decode_rows_independent(weights):
    """A row's output must not depend on other rows in the batch."""
    rng = np.random.default_rng(4)
    p = 21
    prompt = jnp.asarray(rng.integers(0, CFG.vocab, size=(p,)), jnp.int32)
    t0, kc, vc = full_prefill(weights, prompt, use_pallas=False)

    def decode_once(batch):
        kcd = jnp.zeros(M.kv_cache_shape_decode(CFG, batch), jnp.float32)
        vcd = jnp.zeros_like(kcd)
        lens = []
        toks = []
        for i in range(batch):
            kcd = kcd.at[:, i].set(kc)
            vcd = vcd.at[:, i].set(vc)
            lens.append(p)
            toks.append(int(t0))
        t, _, _ = M.decode_step(
            CFG, weights,
            jnp.asarray(toks, jnp.int32), jnp.asarray(lens, jnp.int32),
            kcd, vcd, use_pallas=True,
        )
        return int(t[0])

    assert decode_once(1) == decode_once(4)


def test_prefill_padding_is_harmless(weights):
    """Padded tail tokens of a chunk must not change the KV prefix or
    the first-token logits."""
    rng = np.random.default_rng(5)
    p = 30
    prompt = jnp.asarray(rng.integers(0, CFG.vocab, size=(p,)), jnp.int32)
    kc0 = jnp.zeros(M.kv_cache_shape_prefill(CFG), jnp.float32)
    vc0 = jnp.zeros_like(kc0)
    padded_a = jnp.zeros((64,), jnp.int32).at[:p].set(prompt)
    padded_b = jnp.full((64,), 7, jnp.int32).at[:p].set(prompt)
    ta, kca, _ = M.prefill_chunk(CFG, weights, padded_a, jnp.int32(0), jnp.int32(p), kc0, vc0)
    tb, kcb, _ = M.prefill_chunk(CFG, weights, padded_b, jnp.int32(0), jnp.int32(p), kc0, vc0)
    assert int(ta) == int(tb)
    np.testing.assert_allclose(kca[:, :p], kcb[:, :p], atol=1e-6)


def test_greedy_decode_is_deterministic(weights):
    rng = np.random.default_rng(6)
    prompt = jnp.asarray(rng.integers(0, CFG.vocab, size=(12,)), jnp.int32)
    t0, kc, vc = full_prefill(weights, prompt, use_pallas=True)
    runs = []
    for _ in range(2):
        kcd = jnp.zeros(M.kv_cache_shape_decode(CFG, 1), jnp.float32).at[:, 0].set(kc)
        vcd = jnp.zeros(M.kv_cache_shape_decode(CFG, 1), jnp.float32).at[:, 0].set(vc)
        t = jnp.asarray([int(t0)], jnp.int32)
        l = jnp.asarray([12], jnp.int32)
        seq = []
        for _ in range(6):
            t, kcd, vcd = M.decode_step(CFG, weights, t, l, kcd, vcd)
            l = l + 1
            seq.append(int(t[0]))
        runs.append(seq)
    assert runs[0] == runs[1]


if __name__ == "__main__":
    pytest.main([__file__, "-q"])
