"""Golden-trajectory generator tests (the Rust round-trip fixture)."""

import json
import sys

import jax.numpy as jnp
import numpy as np
import pytest

from compile import golden
from compile import model as M

CFG = M.SMALL_CONFIG


@pytest.fixture(scope="module")
def weights():
    return [jnp.asarray(w) for w in M.init_weights(CFG, seed=0)]


def test_trajectory_shape_and_determinism(weights):
    prompt = [int(x) for x in np.random.default_rng(0).integers(0, CFG.vocab, 12)]
    a = golden.trajectory(CFG, weights, prompt, steps=4)
    b = golden.trajectory(CFG, weights, prompt, steps=4)
    assert a == b
    assert a["prompt"] == prompt
    assert len(a["tokens"]) == 5
    assert all(0 <= t < CFG.vocab for t in a["tokens"])


def test_main_writes_valid_json(tmp_path):
    argv = sys.argv
    sys.argv = ["golden", "--out-dir", str(tmp_path)]
    try:
        golden.main()
    finally:
        sys.argv = argv
    data = json.loads((tmp_path / "golden.json").read_text())
    assert data["model"] == CFG.name
    assert len(data["cases"]) == 3
    lens = sorted(len(c["prompt"]) for c in data["cases"])
    assert lens == [9, 70, 150]
    for c in data["cases"]:
        assert len(c["tokens"]) == 9  # first + 8 decode steps


if __name__ == "__main__":
    pytest.main([__file__, "-q"])
