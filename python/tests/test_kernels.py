"""L1 correctness: Pallas kernels vs pure-jnp oracles.

Hypothesis sweeps shapes/dtypes per the repo's testing contract; every
property asserts allclose against ref.py.
"""

import numpy as np
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import ref
from compile.kernels.decode_attention import gqa_decode_attention_pallas
from compile.kernels.fused_ffn import swiglu_ffn_pallas
from compile.kernels.prefill_attention import causal_prefill_attention_pallas

ATOL = 3e-5
RTOL = 3e-5


def rand(rng, *shape, scale=1.0):
    return jnp.asarray(rng.normal(0.0, scale, size=shape), jnp.float32)


# ---------------------------------------------------------------- decode

@settings(max_examples=25, deadline=None)
@given(
    b=st.integers(1, 5),
    hkv=st.sampled_from([1, 2, 4]),
    group=st.sampled_from([1, 2, 4]),
    dh=st.sampled_from([16, 32, 64]),
    max_len=st.integers(3, 300),
    block_l=st.sampled_from([32, 64, 128]),
    seed=st.integers(0, 2**31),
)
def test_decode_attention_matches_ref(b, hkv, group, dh, max_len, block_l, seed):
    rng = np.random.default_rng(seed)
    hq = hkv * group
    q = rand(rng, b, hq, dh)
    k = rand(rng, b, max_len, hkv, dh)
    v = rand(rng, b, max_len, hkv, dh)
    lens = jnp.asarray(rng.integers(1, max_len + 1, size=(b,)), jnp.int32)
    got = gqa_decode_attention_pallas(q, k, v, lens, block_l=block_l)
    want = ref.gqa_decode_attention(q, k, v, lens)
    np.testing.assert_allclose(got, want, atol=ATOL, rtol=RTOL)


def test_decode_attention_len_one():
    """kv_len=1 must attend only to position 0."""
    rng = np.random.default_rng(0)
    q = rand(rng, 1, 4, 32)
    k = rand(rng, 1, 64, 2, 32)
    v = rand(rng, 1, 64, 2, 32)
    lens = jnp.asarray([1], jnp.int32)
    got = gqa_decode_attention_pallas(q, k, v, lens)
    # With one valid position softmax weight is 1: output = v broadcast.
    want = jnp.repeat(v[:, 0], 2, axis=1)
    np.testing.assert_allclose(got, want, atol=ATOL, rtol=RTOL)


def test_decode_attention_invariant_to_padding_garbage():
    """Values beyond kv_len must not affect the output."""
    rng = np.random.default_rng(1)
    q = rand(rng, 2, 4, 32)
    k = rand(rng, 2, 100, 2, 32)
    v = rand(rng, 2, 100, 2, 32)
    lens = jnp.asarray([10, 60], jnp.int32)
    out1 = gqa_decode_attention_pallas(q, k, v, lens)
    k2 = k.at[:, 60:].set(1e6)
    v2 = v.at[:, 60:].set(-1e6)
    # row 0: garbage also within [10, 60)
    k2 = k2.at[0, 10:].set(1e6)
    v2 = v2.at[0, 10:].set(-1e6)
    out2 = gqa_decode_attention_pallas(q, k2, v2, lens)
    np.testing.assert_allclose(out1, out2, atol=ATOL, rtol=RTOL)


def test_decode_attention_softmax_scale():
    """Known 2-position case computes the exact softmax mixture."""
    dh = 16
    q = jnp.zeros((1, 1, dh)).at[0, 0, 0].set(1.0)
    k = jnp.zeros((1, 2, 1, dh))
    k = k.at[0, 0, 0, 0].set(1.0)  # score = 1/sqrt(dh)
    k = k.at[0, 1, 0, 0].set(0.0)  # score = 0
    v = jnp.zeros((1, 2, 1, dh))
    v = v.at[0, 0, 0, 1].set(1.0)
    v = v.at[0, 1, 0, 2].set(1.0)
    lens = jnp.asarray([2], jnp.int32)
    out = gqa_decode_attention_pallas(q, k, v, lens)
    s = float(1.0 / np.sqrt(dh))
    w0 = float(np.exp(s) / (np.exp(s) + 1.0))
    np.testing.assert_allclose(out[0, 0, 1], w0, atol=1e-5)
    np.testing.assert_allclose(out[0, 0, 2], 1.0 - w0, atol=1e-5)


# --------------------------------------------------------------- prefill

@settings(max_examples=25, deadline=None)
@given(
    t=st.integers(1, 130),
    ctx=st.integers(0, 120),
    hkv=st.sampled_from([1, 2]),
    group=st.sampled_from([1, 2, 4]),
    dh=st.sampled_from([16, 32, 64]),
    block_q=st.sampled_from([16, 32, 64]),
    block_k=st.sampled_from([32, 64]),
    seed=st.integers(0, 2**31),
)
def test_prefill_attention_matches_ref(t, ctx, hkv, group, dh, block_q, block_k, seed):
    rng = np.random.default_rng(seed)
    hq = hkv * group
    q = rand(rng, t, hq, dh)
    k = rand(rng, ctx + t, hkv, dh)
    v = rand(rng, ctx + t, hkv, dh)
    got = causal_prefill_attention_pallas(q, k, v, ctx, block_q=block_q, block_k=block_k)
    want = ref.causal_prefill_attention(q, k, v, ctx)
    np.testing.assert_allclose(got, want, atol=ATOL, rtol=RTOL)


def test_prefill_first_token_attends_only_itself():
    rng = np.random.default_rng(2)
    q = rand(rng, 8, 2, 16)
    k = rand(rng, 8, 1, 16)
    v = rand(rng, 8, 1, 16)
    out = causal_prefill_attention_pallas(q, k, v, 0, block_q=16, block_k=16)
    # Row 0 sees only k[0]: softmax over one element → v[0].
    want0 = jnp.broadcast_to(v[0], (2, 16))
    np.testing.assert_allclose(out[0], want0, atol=ATOL, rtol=RTOL)


def test_prefill_chunk_equals_full_prefill_suffix():
    """Chunked prefill (ctx>0) must equal the suffix of a full prefill."""
    rng = np.random.default_rng(3)
    total, hq, hkv, dh = 96, 4, 2, 32
    split = 40
    q = rand(rng, total, hq, dh)
    k = rand(rng, total, hkv, dh)
    v = rand(rng, total, hkv, dh)
    full = ref.causal_prefill_attention(q, k, v, 0)
    chunk = causal_prefill_attention_pallas(
        q[split:], k, v, split, block_q=32, block_k=32
    )
    np.testing.assert_allclose(chunk, full[split:], atol=ATOL, rtol=RTOL)


# ------------------------------------------------------------------ ffn

@settings(max_examples=25, deadline=None)
@given(
    t=st.integers(1, 150),
    h=st.sampled_from([32, 64, 128, 256]),
    f=st.sampled_from([48, 100, 256, 688]),
    block_m=st.sampled_from([16, 32, 64]),
    block_f=st.sampled_from([32, 64, 256]),
    seed=st.integers(0, 2**31),
)
def test_fused_ffn_matches_ref(t, h, f, block_m, block_f, seed):
    rng = np.random.default_rng(seed)
    x = rand(rng, t, h, scale=0.3)
    wg = rand(rng, h, f, scale=1.0 / np.sqrt(h))
    wu = rand(rng, h, f, scale=1.0 / np.sqrt(h))
    wd = rand(rng, f, h, scale=1.0 / np.sqrt(f))
    got = swiglu_ffn_pallas(x, wg, wu, wd, block_m=block_m, block_f=block_f)
    want = ref.swiglu_ffn(x, wg, wu, wd)
    np.testing.assert_allclose(got, want, atol=2e-4, rtol=2e-4)


def test_ffn_zero_input_is_zero():
    x = jnp.zeros((8, 64))
    wg = jnp.ones((64, 96))
    wu = jnp.ones((64, 96))
    wd = jnp.ones((96, 64))
    out = swiglu_ffn_pallas(x, wg, wu, wd, block_m=16, block_f=32)
    np.testing.assert_allclose(out, jnp.zeros((8, 64)), atol=1e-7)


def test_ffn_linearity_in_down_projection():
    """Scaling w_down scales the output (checks the accumulator carry)."""
    rng = np.random.default_rng(4)
    x = rand(rng, 10, 32, scale=0.3)
    wg = rand(rng, 32, 100, scale=0.2)
    wu = rand(rng, 32, 100, scale=0.2)
    wd = rand(rng, 100, 32, scale=0.2)
    a = swiglu_ffn_pallas(x, wg, wu, wd, block_m=16, block_f=32)
    b = swiglu_ffn_pallas(x, wg, wu, 2.0 * wd, block_m=16, block_f=32)
    np.testing.assert_allclose(2.0 * a, b, atol=1e-4, rtol=1e-4)


# ------------------------------------------------------------------ rope

def test_rope_preserves_norm():
    rng = np.random.default_rng(5)
    x = rand(rng, 12, 4, 32)
    pos = jnp.arange(12, dtype=jnp.int32) + 7
    y = ref.rope(x, pos)
    np.testing.assert_allclose(
        jnp.linalg.norm(y, axis=-1), jnp.linalg.norm(x, axis=-1), atol=1e-4, rtol=1e-4
    )


def test_rope_position_zero_is_identity():
    rng = np.random.default_rng(6)
    x = rand(rng, 1, 2, 16)
    y = ref.rope(x, jnp.zeros((1,), jnp.int32))
    np.testing.assert_allclose(y, x, atol=1e-6)


def test_rope_relative_property():
    """<rope(q,m), rope(k,n)> depends only on m-n (the RoPE invariant)."""
    rng = np.random.default_rng(7)
    q = rand(rng, 1, 1, 32)
    k = rand(rng, 1, 1, 32)
    def dot_at(m, n):
        qm = ref.rope(q, jnp.asarray([m], jnp.int32))[0, 0]
        kn = ref.rope(k, jnp.asarray([n], jnp.int32))[0, 0]
        return float(qm @ kn)
    assert abs(dot_at(5, 3) - dot_at(12, 10)) < 1e-4
    assert abs(dot_at(9, 9) - dot_at(0, 0)) < 1e-4


if __name__ == "__main__":
    pytest.main([__file__, "-q"])
