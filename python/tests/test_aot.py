"""AOT pipeline tests: HLO text is produced, parseable-looking, stable,
and the weights.bin + manifest ABI is consistent."""

import json
import os
import tempfile

import numpy as np
import pytest

from compile import aot
from compile import model as M

CFG = M.SMALL_CONFIG


def test_decode_hlo_text_structure():
    text = aot.lower_decode(CFG, batch=2)
    assert text.startswith("HloModule"), text[:80]
    assert "ENTRY" in text
    # return_tuple=True → tuple root with 3 results
    assert "tuple(" in text.replace(" ", "") or "tuple " in text


def test_prefill_hlo_text_structure():
    text = aot.lower_prefill(CFG, chunk=64)
    assert text.startswith("HloModule")
    assert "ENTRY" in text


def test_hlo_is_deterministic():
    a = aot.lower_decode(CFG, batch=1)
    b = aot.lower_decode(CFG, batch=1)
    assert a == b


def test_pallas_and_ref_lower_to_different_hlo():
    """Sanity: the pallas path actually changes the lowered program."""
    pal = aot.lower_decode(CFG, batch=1, use_pallas=True)
    ref = aot.lower_decode(CFG, batch=1, use_pallas=False)
    assert pal != ref


def test_weights_bin_roundtrip():
    with tempfile.TemporaryDirectory() as d:
        table = aot.write_weights(CFG, d, seed=0)
        raw = open(os.path.join(d, "weights.bin"), "rb").read()
        specs = M.weight_specs(CFG)
        assert len(table) == len(specs)
        expected = M.init_weights(CFG, seed=0)
        total = 0
        for entry, (name, shape), w in zip(table, specs, expected):
            assert entry["name"] == name
            assert tuple(entry["shape"]) == shape
            n = int(np.prod(shape)) * 4
            assert entry["bytes"] == n
            got = np.frombuffer(
                raw[entry["offset"] : entry["offset"] + n], "<f4"
            ).reshape(shape)
            np.testing.assert_array_equal(got, w)
            total += n
        assert len(raw) == total


def test_main_writes_all_artifacts(tmp_path):
    import sys
    argv = sys.argv
    sys.argv = ["aot", "--out-dir", str(tmp_path)]
    try:
        aot.main()
    finally:
        sys.argv = argv
    manifest = json.loads((tmp_path / "manifest.json").read_text())
    assert manifest["model"]["name"] == CFG.name
    files = {e["file"] for e in manifest["executables"]}
    for b in aot.DECODE_BATCH_BUCKETS:
        assert f"decode_b{b}.hlo.txt" in files
    for t in aot.PREFILL_CHUNK_BUCKETS:
        assert f"prefill_t{t}.hlo.txt" in files
    for f in files:
        assert (tmp_path / f).stat().st_size > 1000
    assert (tmp_path / "weights.bin").stat().st_size > 1_000_000


if __name__ == "__main__":
    pytest.main([__file__, "-q"])
